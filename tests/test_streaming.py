"""Chunked streaming ingest (core.streaming): determinism, chunk invariance,
and equivalence with one-shot processing — the contracts that make the fused
path safe to deploy against unbounded streams."""
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import GroupedQuantileSketch, ingest_array, ingest_stream
from repro.core.reference import relative_mass_error


def _items(t, g, seed=0, domain=500):
    rng = np.random.default_rng(seed)
    return rng.integers(0, domain, (t, g)).astype(np.float32)


@pytest.mark.parametrize("algo", ["1u", "2u"])
def test_ingest_stream_bit_identical_to_one_shot_process(algo):
    t, g = 700, 33
    items = _items(t, g, seed=1)
    key = jax.random.PRNGKey(3)
    sk = GroupedQuantileSketch.create(g, quantile=0.5, algo=algo)
    one_shot = sk.process(jnp.asarray(items), key)
    streamed = ingest_stream(
        sk, [items[:123], items[123:400], items[400:]], key, chunk_t=64)
    np.testing.assert_array_equal(np.asarray(one_shot.m), np.asarray(streamed.m))
    if algo == "2u":
        np.testing.assert_array_equal(np.asarray(one_shot.step),
                                      np.asarray(streamed.step))
        np.testing.assert_array_equal(np.asarray(one_shot.sign),
                                      np.asarray(streamed.sign))


@pytest.mark.parametrize("chunk_t", [32, 100, 256, 1024])
def test_ingest_is_chunk_size_invariant(chunk_t):
    """Absolute-tick RNG keying: chunk_t must not change one bit."""
    t, g = 500, 17
    items = _items(t, g, seed=2)
    key = jax.random.PRNGKey(5)
    sk = GroupedQuantileSketch.create(g, quantile=0.9, algo="2u")
    base = sk.process(jnp.asarray(items), key)
    sa = ingest_array(sk, jnp.asarray(items), key, chunk_t=chunk_t)
    ss = ingest_stream(sk, [items], key, chunk_t=chunk_t)
    for got in (sa, ss):
        np.testing.assert_array_equal(np.asarray(base.m), np.asarray(got.m))
        np.testing.assert_array_equal(np.asarray(base.step), np.asarray(got.step))


def test_ingest_stream_boundary_invariant():
    """How the producer slices the stream must not matter either."""
    t, g = 300, 5
    items = _items(t, g, seed=3)
    key = jax.random.PRNGKey(11)
    sk = GroupedQuantileSketch.create(g, quantile=0.25, algo="2u")
    a = ingest_stream(sk, [items], key, chunk_t=128)
    rng = np.random.default_rng(0)
    cuts = np.sort(rng.choice(np.arange(1, t), 7, replace=False))
    pieces = np.split(items, cuts)
    b = ingest_stream(sk, pieces, key, chunk_t=128)
    np.testing.assert_array_equal(np.asarray(a.m), np.asarray(b.m))
    np.testing.assert_array_equal(np.asarray(a.step), np.asarray(b.step))


def test_ingest_stream_from_generator_converges():
    """An actual generator (unbounded-stream shape): no [T, G] block ever
    exists host- or device-side, yet estimates converge like the paper says."""
    g, n_chunks, per = 8, 60, 512
    key = jax.random.PRNGKey(7)
    master = np.random.default_rng(9)
    pooled = []

    def producer():
        for _ in range(n_chunks):
            x = master.lognormal(5.0, 1.0, (per, g)).astype(np.float32)
            pooled.append(x)
            yield x

    sk = GroupedQuantileSketch.create(g, quantile=0.5, algo="2u", init=100.0)
    sk = ingest_stream(sk, producer(), key, chunk_t=2048)
    allx = np.concatenate(pooled, 0)
    for gi in range(g):
        err = relative_mass_error(float(sk.m[gi]),
                                  sorted(allx[:, gi].tolist()), 0.5)
        assert abs(err) < 0.08, f"group {gi} mass error {err:+.3f}"


def test_ingest_scalar_stream_1d_chunks():
    """G == 1 sketches accept 1-D chunks (the paper's single-stream view)."""
    sk = GroupedQuantileSketch.create(1, quantile=0.5, algo="2u", init=0.0)
    rng = np.random.default_rng(4)
    sk = ingest_stream(sk, (rng.normal(40.0, 10.0, 997).astype(np.float32)
                            for _ in range(20)),
                       jax.random.PRNGKey(0), chunk_t=512)
    assert 25.0 < float(sk.m[0]) < 55.0


def test_ingest_stream_survives_int32_tick_wraparound():
    """Past 2^31 absolute ticks the counter wraps instead of raising
    OverflowError — the unbounded-stream contract. Simulated by starting
    the rechunker near the boundary via many chunks... too slow to reach
    for real, so exercise the wrap helper plus a kernel call at the edge."""
    from repro.core import program as program_mod
    from repro.core import rng as crng
    from repro.kernels import ops

    assert crng.wrap_i32(2**31) == -(2**31)
    assert crng.wrap_i32(2**31 - 1) == 2**31 - 1
    assert crng.wrap_i32(2**32 + 5) == 5
    # a fused call at a wrapped offset must execute cleanly
    (m,) = ops.frugal_update_auto(
        jnp.ones((8, 4), jnp.float32), (jnp.zeros((4,), jnp.float32),), 0.5,
        seed=1, program=program_mod.family_base("1u"),
        t_offset=crng.wrap_i32(2**31 + 3))
    assert m.shape == (4,)
    assert bool(jnp.all(jnp.isfinite(m)))
    # both continuation entry points wrap a past-2^31 t_offset identically
    # instead of raising OverflowError at the int32 conversion
    sk = GroupedQuantileSketch.create(4, quantile=0.5, algo="2u")
    items = np.ones((16, 4), np.float32)
    key = jax.random.PRNGKey(0)
    a = ingest_array(sk, items, key, chunk_t=8, t_offset=2**31 + 3)
    b = ingest_stream(sk, [items], key, chunk_t=8, t_offset=2**31 + 3)
    np.testing.assert_array_equal(np.asarray(a.m), np.asarray(b.m))


def test_ingest_stream_rejects_bad_shapes():
    sk = GroupedQuantileSketch.create(4, quantile=0.5)
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError):
        ingest_stream(sk, [np.zeros((10, 3), np.float32)], key)
    with pytest.raises(ValueError):
        ingest_stream(sk, [np.zeros(10, np.float32)], key)  # 1-D but G=4
    with pytest.raises(ValueError):
        ingest_stream(sk, [np.zeros((10, 4), np.float32)], key, chunk_t=0)


@pytest.mark.slow
@pytest.mark.skipif(not os.environ.get("RUN_SOAK"),
                    reason="long-stream soak (~10^8 items); opt in with "
                           "RUN_SOAK=1 (see EXPERIMENTS.md)")
def test_long_stream_soak_1e8_items():
    """The EXPERIMENTS.md long-stream-soak owner: stream >= 10^8 items
    (ticks × groups) through ingest_stream from a generator — no [T, G]
    block ever resident, bounded memory, sane walltime, converged estimates.
    SOAK_ITEMS overrides the default volume for bigger runs."""
    total = int(float(os.environ.get("SOAK_ITEMS", 1e8)))
    g, per = 4096, 4096
    n_chunks = max(1, -(-total // (g * per)))   # ceil: stream >= `total`
    key = jax.random.PRNGKey(0)
    master = np.random.default_rng(42)

    def producer():
        for _ in range(n_chunks):
            yield master.lognormal(5.0, 1.0, (per, g)).astype(np.float32)

    sk = GroupedQuantileSketch.create(g, quantile=0.5, algo="2u", init=100.0)
    t0 = time.perf_counter()
    sk = ingest_stream(sk, producer(), key, chunk_t=4096)
    wall = time.perf_counter() - t0
    items = n_chunks * per * g
    gb = items * 4 / 1e9
    print(f"\nsoak: {items:.2e} items ({gb:.1f} GB) in {wall:.1f}s "
          f"-> {items / wall / 1e6:.1f}M items/s, {gb / wall:.2f} GB/s")
    m = np.asarray(sk.m)
    # lognormal(5, 1) true median = e^5 ~ 148.4; after ~24k ticks every
    # group must sit well inside the Thm-2 band around it
    assert np.all(np.isfinite(m))
    assert abs(np.median(m) - np.exp(5.0)) < 30.0
    assert np.all(np.abs(m - np.exp(5.0)) < 80.0)


def test_ingest_array_matches_stream_with_padding_tail():
    """T not a multiple of chunk_t: the NaN-padded tail must be a no-op."""
    t, g = 777, 9
    items = _items(t, g, seed=8)
    key = jax.random.PRNGKey(2)
    sk = GroupedQuantileSketch.create(g, quantile=0.5, algo="1u")
    a = ingest_array(sk, jnp.asarray(items), key, chunk_t=256)
    b = ingest_stream(sk, [items], key, chunk_t=256)
    c = sk.process(jnp.asarray(items), key)
    np.testing.assert_array_equal(np.asarray(a.m), np.asarray(b.m))
    np.testing.assert_array_equal(np.asarray(a.m), np.asarray(c.m))


# ----------------------------------------------- rechunk_blocks edge cases
# The re-chunker is shared by ingest_stream, the sharded fleet and the api
# facade — its (block, t_offset) bookkeeping IS the cursor contract, so the
# degenerate stream shapes are pinned bit-exactly here.
def test_rechunk_empty_iterator_yields_nothing():
    from repro.core.streaming import rechunk_blocks

    assert list(rechunk_blocks(iter([]), num_groups=4, chunk_t=16)) == []
    # and ingesting an empty stream is a no-op that leaves state untouched
    sk = GroupedQuantileSketch.create(4, quantile=0.5, algo="2u")
    out = ingest_stream(sk, iter([]), jax.random.PRNGKey(0), chunk_t=16)
    np.testing.assert_array_equal(np.asarray(sk.m), np.asarray(out.m))
    np.testing.assert_array_equal(np.asarray(sk.step), np.asarray(out.step))


def test_rechunk_zero_length_blocks_mid_stream_are_invisible():
    """[0, G] blocks interleaved anywhere must not perturb blocking or
    t_offsets — the re-chunked output is bit-identical to the same stream
    without them."""
    from repro.core.streaming import rechunk_blocks

    g, chunk_t = 5, 8
    items = _items(30, g, seed=7)
    empty = np.zeros((0, g), np.float32)
    with_empties = [empty, items[:3], empty, empty, items[3:20], empty,
                    items[20:], empty]
    ref = list(rechunk_blocks([items], g, chunk_t))
    got = list(rechunk_blocks(with_empties, g, chunk_t))
    assert len(ref) == len(got) == 4   # ceil(30 / 8)
    for (rb, rt), (gb, gt) in zip(ref, got):
        assert rt == gt
        np.testing.assert_array_equal(rb, gb)
    # t_offsets advance by exactly chunk_t per emitted block
    assert [t for _, t in got] == [0, 8, 16, 24]
    # and the full ingest trajectories agree bit-for-bit
    key = jax.random.PRNGKey(11)
    sk = GroupedQuantileSketch.create(g, quantile=0.7, algo="2u")
    a = ingest_stream(sk, [items], key, chunk_t=chunk_t)
    b = ingest_stream(sk, with_empties, key, chunk_t=chunk_t)
    np.testing.assert_array_equal(np.asarray(a.m), np.asarray(b.m))
    np.testing.assert_array_equal(np.asarray(a.step), np.asarray(b.step))


def test_rechunk_stream_shorter_than_one_chunk():
    """A sub-chunk stream yields ONE NaN-padded block at t_offset 0, the
    pad rows are bit-exact no-ops, and a facade cursor advances by the REAL
    item count (not the padded block size)."""
    from repro.core.streaming import rechunk_blocks

    g, chunk_t, t = 3, 64, 10
    items = _items(t, g, seed=9)
    blocks = list(rechunk_blocks([items[:4], items[4:]], g, chunk_t))
    assert len(blocks) == 1
    block, t0 = blocks[0]
    assert t0 == 0 and block.shape == (chunk_t, g)
    np.testing.assert_array_equal(block[:t], items)
    assert np.all(np.isnan(block[t:]))

    key = jax.random.PRNGKey(2)
    sk = GroupedQuantileSketch.create(g, quantile=0.5, algo="2u")
    one_shot = sk.process(jnp.asarray(items), key)
    streamed = ingest_stream(sk, [items[:4], items[4:]], key, chunk_t=chunk_t)
    np.testing.assert_array_equal(np.asarray(one_shot.m),
                                  np.asarray(streamed.m))

    from repro.api import FleetSpec, QuantileFleet
    from repro.core import rng as crng

    seed = int(np.asarray(crng.seed_from_key(key)))
    fleet = QuantileFleet.create(
        FleetSpec(num_groups=g, quantiles=(0.5,), chunk_t=chunk_t), seed=seed)
    fleet = fleet.ingest_stream([items[:4], items[4:]])
    assert int(fleet.cursor.t_offset) == t   # real items, not chunk_t
    np.testing.assert_array_equal(fleet.estimate(0.5), np.asarray(one_shot.m))
    # continuing the stream reproduces an unbroken run (the padded tail of
    # the first call's final block replays as real ticks — no-ops consumed
    # nothing)
    more = _items(20, g, seed=10)
    cont = fleet.ingest_stream([more])
    full = sk.process(jnp.asarray(np.concatenate([items, more])), key)
    np.testing.assert_array_equal(cont.estimate(0.5), np.asarray(full.m))
