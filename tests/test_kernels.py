"""Program-kernel validation: the ONE Pallas kernel family
(kernels.frugal_update via kernels.ops.frugal_update_blocked) must match
the independent jnp oracles (kernels/ref.py) and the program-generic scan
bit-for-bit, for every registered program, across shapes and block tilings
(interpret mode executes the kernel body on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# Only the property tests need hypothesis; a missing dev dep must not kill
# collection of the whole suite under `pytest -x` (see requirements-dev.txt).
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

from repro.core import program as program_mod
from repro.core.frugal import program_process_seeded
from repro.kernels import frugal_update_blocked
from repro.kernels import ref

pytestmark = pytest.mark.kernel

SEED = 2024


def _mk(t, g, seed=0, dtype=np.float32, domain=200):
    rng = np.random.default_rng(seed)
    items = rng.integers(0, domain, size=(t, g)).astype(dtype)
    m = rng.integers(0, domain, size=g).astype(np.float32)
    return jnp.asarray(items), jnp.asarray(m)


def _init_planes(program, m):
    """Program planes from an m vector: heads start at m (copies), pair
    planes at 1 — the same convention GroupedQuantileSketch.create uses."""
    layout = program.layout
    return tuple(
        m if f == "m" else (jnp.array(m) if f in layout.heads
                            else jnp.ones_like(m))
        for f in layout.plane_fields)


SHAPES = [
    (1, 1), (7, 3), (64, 128), (256, 128), (300, 130),  # non-multiples too
    (512, 256), (1024, 64), (33, 257),
]


@pytest.mark.parametrize("t,g", SHAPES)
@pytest.mark.parametrize("q", [0.1, 0.5, 0.9])
def test_program_kernel_1u_matches_independent_ref(t, g, q):
    items, m = _mk(t, g, seed=t * 1000 + g)
    qv = jnp.full((g,), q, jnp.float32)
    prog = program_mod.family_base("1u")
    (got,) = frugal_update_blocked(items, (m,), qv, SEED, program=prog,
                                   interpret=True)
    want = ref.frugal1u_ref_fused(items, m, qv, SEED)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("t,g", SHAPES)
@pytest.mark.parametrize("q", [0.1, 0.5, 0.9])
def test_program_kernel_2u_matches_independent_ref(t, g, q):
    items, m = _mk(t, g, seed=t * 7 + g)
    step = jnp.ones((g,), jnp.float32)
    sign = jnp.ones((g,), jnp.float32)
    qv = jnp.full((g,), q, jnp.float32)
    prog = program_mod.family_base("2u")
    got = frugal_update_blocked(items, (m, step, sign), qv, SEED,
                                program=prog, interpret=True)
    want = ref.frugal2u_ref_fused(items, m, step, sign, qv, SEED)
    for a, b, name in zip(got, want, ("m", "step", "sign")):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{name} mismatch at ({t},{g},q={q})")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtype_sweep(dtype):
    """Items may arrive bf16 (activations); state math runs in f32."""
    t, g = 128, 128
    rng = np.random.default_rng(3)
    items = jnp.asarray(rng.integers(0, 50, (t, g)), dtype)
    m = jnp.zeros((g,), jnp.float32)
    qv = jnp.full((g,), 0.5, jnp.float32)
    prog = program_mod.family_base("1u")
    (got,) = frugal_update_blocked(items, (m,), qv, SEED, program=prog,
                                   interpret=True)
    want = ref.frugal1u_ref_fused(items.astype(jnp.float32), m, qv, SEED)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _scan_planes(program, items, planes, qv, seed):
    out, _ = program_process_seeded(program, planes, items, seed, qv)
    return tuple(np.asarray(p) for p in out)


def test_program_kernel_block_shape_sweep_every_family():
    """Block shapes must not change a single bit of any program's result
    (absolute-index RNG keys + VMEM-resident plane state). One loop over
    the registry is the whole tiling matrix — the five per-rule sweeps this
    replaces are a registry entry each."""
    t, g = 160, 130
    items, m = _mk(t, g, seed=11)
    qv = jnp.full((g,), 0.7, jnp.float32)
    for prog in program_mod.test_instances():
        planes = _init_planes(prog, jnp.zeros((g,), jnp.float32))
        want = _scan_planes(prog, items, planes, qv, SEED)
        for bg in (64, 128):
            for bt in (32, 256):
                got = frugal_update_blocked(items, planes, qv, SEED,
                                            program=prog, block_g=bg,
                                            block_t=bt, interpret=True)
                for f, a, b in zip(prog.layout.plane_fields, got, want):
                    np.testing.assert_array_equal(
                        np.asarray(a), b,
                        err_msg=f"{prog.family} {f} block ({bt},{bg})")


def test_kernel_nan_padding_is_noop():
    """NaN ticks must leave state untouched (the ragged/padding contract),
    for every registered program — including the window rules, whose epoch
    restarts are gated on item validity."""
    t, g = 64, 128
    items, m = _mk(t, g, seed=5)
    qv = jnp.full((g,), 0.5, jnp.float32)
    items2 = jnp.concatenate([items, jnp.full((32, g), jnp.nan, jnp.float32)])
    for prog in program_mod.test_instances():
        planes = _init_planes(prog, m)
        out1 = frugal_update_blocked(items, planes, qv, SEED, program=prog,
                                     interpret=True)
        out2 = frugal_update_blocked(items2, planes, qv, SEED, program=prog,
                                     interpret=True)
        for f, a, b in zip(prog.layout.plane_fields, out1, out2):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{prog.family} {f} perturbed by NaN ticks")


def test_scatter_kernel_matches_jnp_sparse_every_family():
    """The event-round scatter kernel (gather→tick→scatter against resident
    state, input_output_aliases) must replay the jnp sparse path bit-for-bit
    for every registered program: multi-block grids, non-zero g_offset,
    mask-0 NaN pad slots, and K not a multiple of block_k (internal pad)."""
    from repro.kernels import ops as kernel_ops

    L, g_off = 96, 1000
    rng = np.random.default_rng(31)
    m0 = jnp.asarray(rng.integers(0, 200, L), jnp.float32)
    qv = jnp.asarray(rng.choice([0.1, 0.5, 0.9], L), jnp.float32)
    for prog in program_mod.test_instances():
        planes_j = _init_planes(prog, m0)
        planes_p = tuple(jnp.array(p) for p in planes_j)
        ticks_j = jnp.zeros((L,), jnp.int32)
        ticks_p = jnp.zeros((L,), jnp.int32)
        for r, k in enumerate((1, 40, 96, 70)):
            lanes = np.sort(rng.choice(L, k, replace=False)).astype(np.int32)
            vals = rng.integers(0, 200, k).astype(np.float32)
            mask = np.ones(k, np.int32)
            if k < L:   # explicit mask-0 pad on an event-free lane
                pad = next(i for i in range(L)
                           if i not in set(lanes.tolist()))
                lanes = np.append(lanes, np.int32(pad))
                vals = np.append(vals, np.float32(np.nan))
                mask = np.append(mask, np.int32(0))
            planes_j, ticks_j = kernel_ops.frugal_update_sparse(
                lanes, vals, mask, planes_j, ticks_j, qv, SEED,
                program=prog, g_offset=g_off)
            planes_p, ticks_p = kernel_ops.frugal_update_sparse(
                lanes, vals, mask, planes_p, ticks_p, qv, SEED,
                program=prog, g_offset=g_off, block_k=32, interpret=True)
            for f, a, b in zip(prog.layout.plane_fields, planes_j,
                               planes_p):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"{prog.family} plane {f!r} diverges from jnp "
                            f"at round {r}")
            np.testing.assert_array_equal(
                np.asarray(ticks_j), np.asarray(ticks_p),
                err_msg=f"{prog.family} lane clocks diverge at round {r}")


def test_kernel_per_lane_quantiles():
    """One call, heterogeneous quantile targets across lanes."""
    t, g = 2048, 8
    rng = np.random.default_rng(9)
    items = jnp.asarray(rng.integers(0, 1000, (t, g)), jnp.float32)
    m = jnp.full((g,), 500.0, jnp.float32)
    qv = jnp.asarray([0.1, 0.2, 0.3, 0.4, 0.6, 0.7, 0.8, 0.9], jnp.float32)
    step = jnp.ones((g,), jnp.float32)
    sign = jnp.ones((g,), jnp.float32)
    prog = program_mod.family_base("2u")
    m2, _, _ = frugal_update_blocked(items, (m, step, sign), qv, SEED,
                                     program=prog, interpret=True)
    # final estimates must be ordered like their target quantiles (loose check)
    est = np.asarray(m2)
    assert est[0] < est[-1], f"q10 {est[0]} !< q90 {est[-1]}"
    want = ref.frugal2u_ref_fused(items, m, step, sign, qv, SEED)
    np.testing.assert_array_equal(est, np.asarray(want[0]))


def test_rule_scalars_are_dynamic_operands():
    """Two instances of one family with different parameters must share the
    compiled kernel (family_base compile key) yet produce their own
    trajectories — the scalar slots are dynamic operands."""
    t, g = 300, 7
    items, _ = _mk(t, g, seed=8, domain=500)
    qv = jnp.full((g,), 0.3, jnp.float32)
    m0 = jnp.zeros((g,), jnp.float32)
    one = jnp.ones((g,), jnp.float32)
    outs = {}
    for hl in (8, 48):
        prog = program_mod.make_program("2u-decay", half_life=hl)
        got = frugal_update_blocked(items, (m0, one, one), qv, SEED,
                                    program=prog, block_g=4, block_t=64,
                                    interpret=True)
        want = _scan_planes(prog, items, (m0, one, one), qv, SEED)
        for f, a, b in zip(prog.layout.plane_fields, got, want):
            np.testing.assert_array_equal(np.asarray(a), b,
                                          err_msg=f"half_life={hl} {f}")
        outs[hl] = np.asarray(got[1])
    assert not np.array_equal(outs[8], outs[48]), \
        "different half-lives must yield different step trajectories"


if HAS_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        t=st.integers(1, 80),
        g=st.integers(1, 140),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_program_kernel_equals_ref_arbitrary_shapes(t, g, seed):
        items, m = _mk(t, g, seed=seed)
        qv = jnp.full((g,), 0.5, jnp.float32)
        step = jnp.ones((g,), jnp.float32)
        sign = jnp.ones((g,), jnp.float32)
        prog = program_mod.family_base("2u")
        got = frugal_update_blocked(items, (m, step, sign), qv, seed,
                                    program=prog, block_g=128, block_t=64,
                                    interpret=True)
        want = ref.frugal2u_ref_fused(items, m, step, sign, qv, seed)
        for a, b in zip(got, want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @settings(max_examples=10, deadline=None)
    @given(
        t=st.integers(1, 60),
        g=st.integers(1, 100),
        seed=st.integers(0, 2**31 - 1),
        family=st.sampled_from([p.family
                                for p in program_mod.test_instances()]),
    )
    def test_property_program_kernel_equals_scan_arbitrary_shapes(
            t, g, seed, family):
        prog = next(p for p in program_mod.test_instances()
                    if p.family == family)
        items, m = _mk(t, g, seed=seed)
        qv = jnp.full((g,), 0.5, jnp.float32)
        planes = _init_planes(prog, m)
        got = frugal_update_blocked(items, planes, qv, seed, program=prog,
                                    block_g=128, block_t=64, interpret=True)
        want = _scan_planes(prog, items, planes, qv, seed)
        for f, a, b in zip(prog.layout.plane_fields, got, want):
            np.testing.assert_array_equal(np.asarray(a), b,
                                          err_msg=f"{family} {f}")

else:

    def test_property_tests_need_hypothesis():
        pytest.skip("hypothesis not installed — property tests not collected "
                    "(pip install -r requirements-dev.txt)")
