"""Pallas kernel validation: shape/dtype sweep vs the pure-jnp ref oracle
(interpret mode executes the kernel body on CPU; equality must be bit-exact
since both sides consume identical fed-in uniforms)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# Only the property tests need hypothesis; a missing dev dep must not kill
# collection of the whole suite under `pytest -x` (see requirements-dev.txt).
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

from repro.kernels import (
    frugal1u_update_blocked_fused,
    frugal2u_update_blocked_fused,
)
# The fed-uniform sweep drives the rand-operand kernels through their
# warning-free internal impls: tier-1 promotes DeprecationWarning to error
# (pytest.ini), and the deprecation shim's warning is pinned in
# tests/test_deprecations.py — the ONLY place allowed to expect it.
from repro.kernels.ops import (
    _frugal1u_update_blocked as frugal1u_update_blocked,
    _frugal2u_update_blocked as frugal2u_update_blocked,
)
from repro.kernels import ref

pytestmark = pytest.mark.kernel


def _mk(t, g, seed=0, dtype=np.float32, domain=200):
    rng = np.random.default_rng(seed)
    items = rng.integers(0, domain, size=(t, g)).astype(dtype)
    rand = rng.random((t, g)).astype(dtype)
    m = rng.integers(0, domain, size=g).astype(dtype)
    return jnp.asarray(items), jnp.asarray(rand), jnp.asarray(m)


SHAPES = [
    (1, 1), (7, 3), (64, 128), (256, 128), (300, 130),  # non-multiples too
    (512, 256), (1024, 64), (33, 257),
]


@pytest.mark.parametrize("t,g", SHAPES)
@pytest.mark.parametrize("q", [0.1, 0.5, 0.9])
def test_frugal1u_kernel_matches_ref(t, g, q):
    items, rand, m = _mk(t, g, seed=t * 1000 + g)
    qv = jnp.full((g,), q, jnp.float32)
    got = frugal1u_update_blocked(items, rand, m, qv, interpret=True)
    want = ref.frugal1u_ref(items, rand, m, qv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@pytest.mark.parametrize("t,g", SHAPES)
@pytest.mark.parametrize("q", [0.1, 0.5, 0.9])
def test_frugal2u_kernel_matches_ref(t, g, q):
    items, rand, m = _mk(t, g, seed=t * 7 + g)
    step = jnp.ones((g,), jnp.float32)
    sign = jnp.ones((g,), jnp.float32)
    qv = jnp.full((g,), q, jnp.float32)
    got = frugal2u_update_blocked(items, rand, m, step, sign, qv, interpret=True)
    want = ref.frugal2u_ref(items, rand, m, step, sign, qv)
    for a, b, name in zip(got, want, ("m", "step", "sign")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0,
                                   err_msg=f"{name} mismatch at ({t},{g},q={q})")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtype_sweep(dtype):
    """Items may arrive bf16 (activations); state math runs in f32."""
    t, g = 128, 128
    rng = np.random.default_rng(3)
    items = jnp.asarray(rng.integers(0, 50, (t, g)), dtype)
    rand = jnp.asarray(rng.random((t, g)), jnp.float32)
    m = jnp.zeros((g,), jnp.float32)
    qv = jnp.full((g,), 0.5, jnp.float32)
    got = frugal1u_update_blocked(items, rand, m, qv, interpret=True)
    want = ref.frugal1u_ref(items.astype(jnp.float32), rand, m, qv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_kernel_block_shape_sweep():
    """Block shapes must not change results (tiling-invariance)."""
    t, g = 512, 384
    items, rand, m = _mk(t, g, seed=11)
    qv = jnp.full((g,), 0.7, jnp.float32)
    ref_out = np.asarray(ref.frugal1u_ref(items, rand, m, qv))
    for bg in (128, 256):
        for bt in (64, 256, 512):
            got = frugal1u_update_blocked(items, rand, m, qv,
                                          block_g=bg, block_t=bt, interpret=True)
            np.testing.assert_allclose(np.asarray(got), ref_out, rtol=0, atol=0,
                                       err_msg=f"block ({bt},{bg})")


def test_kernel_nan_padding_is_noop():
    """NaN ticks must leave state untouched (the ragged/padding contract)."""
    t, g = 64, 128
    items, rand, m = _mk(t, g, seed=5)
    qv = jnp.full((g,), 0.5, jnp.float32)
    out1 = frugal1u_update_blocked(items, rand, m, qv, interpret=True)
    # append a NaN block
    items2 = jnp.concatenate([items, jnp.full((32, g), jnp.nan, jnp.float32)])
    rand2 = jnp.concatenate([rand, jnp.full((32, g), 0.99, jnp.float32)])
    out2 = frugal1u_update_blocked(items2, rand2, m, qv, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=0, atol=0)


def test_kernel_per_group_quantiles():
    """One call, heterogeneous quantile targets across lanes."""
    t, g = 2048, 8
    rng = np.random.default_rng(9)
    items = jnp.asarray(rng.integers(0, 1000, (t, g)), jnp.float32)
    rand = jnp.asarray(rng.random((t, g)), jnp.float32)
    m = jnp.full((g,), 500.0, jnp.float32)
    qv = jnp.asarray([0.1, 0.2, 0.3, 0.4, 0.6, 0.7, 0.8, 0.9], jnp.float32)
    step = jnp.ones((g,), jnp.float32)
    sign = jnp.ones((g,), jnp.float32)
    m2, _, _ = frugal2u_update_blocked(items, rand, m, step, sign, qv, interpret=True)
    # final estimates must be ordered like their target quantiles (loose check)
    est = np.asarray(m2)
    assert est[0] < est[-1], f"q10 {est[0]} !< q90 {est[-1]}"
    want = ref.frugal2u_ref(items, rand, m, step, sign, qv)
    np.testing.assert_allclose(est, np.asarray(want[0]), rtol=0, atol=0)


def test_fused_kernel_block_shape_sweep():
    """Fused kernels key the RNG on ABSOLUTE (tick, group) indices, so block
    shape must not change a single bit of the result."""
    t, g = 512, 384
    items, _, m = _mk(t, g, seed=21)
    qv = jnp.full((g,), 0.7, jnp.float32)
    step = jnp.ones((g,), jnp.float32)
    sign = jnp.ones((g,), jnp.float32)
    seed = 2024
    ref1 = np.asarray(ref.frugal1u_ref_fused(items, m, qv, seed))
    ref2 = [np.asarray(x) for x in
            ref.frugal2u_ref_fused(items, m, step, sign, qv, seed)]
    for bg in (128, 256):
        for bt in (64, 256, 512):
            got1 = frugal1u_update_blocked_fused(
                items, m, qv, seed, block_g=bg, block_t=bt, interpret=True)
            np.testing.assert_array_equal(np.asarray(got1), ref1,
                                          err_msg=f"1u block ({bt},{bg})")
            got2 = frugal2u_update_blocked_fused(
                items, m, step, sign, qv, seed, block_g=bg, block_t=bt,
                interpret=True)
            for a, b, name in zip(got2, ref2, ("m", "step", "sign")):
                np.testing.assert_array_equal(
                    np.asarray(a), b, err_msg=f"2u {name} block ({bt},{bg})")


if HAS_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        t=st.integers(1, 80),
        g=st.integers(1, 140),
        seed=st.integers(0, 2**31 - 1),
        q=st.sampled_from([0.25, 0.5, 0.75]),
    )
    def test_property_kernel_equals_ref_arbitrary_shapes(t, g, seed, q):
        items, rand, m = _mk(t, g, seed=seed)
        qv = jnp.full((g,), q, jnp.float32)
        step = jnp.ones((g,), jnp.float32)
        sign = jnp.ones((g,), jnp.float32)
        got = frugal2u_update_blocked(items, rand, m, step, sign, qv,
                                      block_g=128, block_t=64, interpret=True)
        want = ref.frugal2u_ref(items, rand, m, step, sign, qv)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)

    @settings(max_examples=15, deadline=None)
    @given(
        t=st.integers(1, 80),
        g=st.integers(1, 140),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_fused_kernel_equals_fused_ref_arbitrary_shapes(t, g, seed):
        items, _, m = _mk(t, g, seed=seed)
        qv = jnp.full((g,), 0.5, jnp.float32)
        step = jnp.ones((g,), jnp.float32)
        sign = jnp.ones((g,), jnp.float32)
        got = frugal2u_update_blocked_fused(items, m, step, sign, qv, seed,
                                            block_g=128, block_t=64,
                                            interpret=True)
        want = ref.frugal2u_ref_fused(items, m, step, sign, qv, seed)
        for a, b in zip(got, want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

else:

    def test_property_tests_need_hypothesis():
        pytest.skip("hypothesis not installed — property tests not collected "
                    "(pip install -r requirements-dev.txt)")
