"""Public-API lint (repro.api.lint): every subpackage `__all__` name must
resolve — export drift (like the near-miss in PR 2's parallel/__init__.py)
fails here AND in the dedicated CI step — and every registered LaneProgram
must be whole (packing spec, query, scalar slots matching the tick's scan
signature)."""
import dataclasses

import pytest

from repro.api.lint import check_programs, check_public_api, iter_subpackages


def test_every_dunder_all_name_resolves():
    exported = check_public_api()
    # the core layers must actually export things — an empty report would
    # mean the walker silently skipped them
    for pkg in ("repro", "repro.api", "repro.core", "repro.core.baselines",
                "repro.kernels", "repro.parallel", "repro.serve",
                "repro.service", "repro.monitor"):
        assert pkg in exported and exported[pkg], f"{pkg} exports nothing?"


def test_walker_sees_only_packages():
    """Leaf modules (e.g. launch.dryrun sets XLA_FLAGS at import) must not
    be imported by the lint walk."""
    names = [name for name, _ in iter_subpackages()]
    assert "repro.launch.dryrun" not in names
    assert "repro.launch" in names


def test_drift_is_reported_with_package_and_name(monkeypatch):
    import repro.api as api_pkg

    monkeypatch.setattr(api_pkg, "__all__",
                        list(api_pkg.__all__) + ["NotARealExport"])
    with pytest.raises(AssertionError, match="NotARealExport"):
        check_public_api()


def test_facade_names_resolve_from_top_level():
    import repro

    for name in ("QuantileFleet", "FleetSpec", "StreamCursor",
                 "QuantileEstimator", "FrugalEstimator"):
        assert getattr(repro, name) is not None


def test_every_registered_program_validates():
    families = check_programs()
    # the five legacy rules plus the DP rule must all be registered
    for fam in ("1u", "2u", "2u-decay", "1u-window", "2u-window", "2u-dp"):
        assert fam in families


def test_half_registered_program_fails_lint():
    """A program whose packing spec does not cover its planes, or whose
    scalar slots do not resolve, must be refused at REGISTRATION (layout
    __post_init__) or by validate_program — never surface as a user-side
    shape error."""
    from repro.core.program import (LaneProgram, StateLayout, family_base,
                                    validate_program)

    with pytest.raises(ValueError, match="packing"):
        StateLayout(plane_fields=("m", "step", "sign"),
                    packing=(("m", None),))       # pairs not enumerated
    with pytest.raises(ValueError, match="query_fields"):
        StateLayout(plane_fields=("m",), packing=(("m", None),),
                    query_fields=("m2",))         # queries a missing plane

    base = family_base("2u")
    # declares a scalar slot its parameters cannot resolve
    broken = dataclasses.replace(
        base, layout=dataclasses.replace(base.layout,
                                         scalar_names=("half_life_ticks",)))
    with pytest.raises((AssertionError, ValueError)):
        validate_program(broken)


def test_program_without_invariants_fails_lint():
    """Every plane field must declare an invariant DOMAIN (resilience.health
    derives lane corruption scanning from these declarations): a program
    stripped of its invariants must fail validate_program, and a layout
    declaring an unknown domain / unknown field / duplicate must be refused
    at construction."""
    from repro.core.program import (StateLayout, family_base,
                                    validate_program)

    base = family_base("2u")
    stripped = dataclasses.replace(
        base, layout=dataclasses.replace(base.layout, invariants=()))
    with pytest.raises(AssertionError, match="invariant"):
        validate_program(stripped)

    # heads must be scanned for finiteness specifically
    wrong_head = dataclasses.replace(
        base, layout=dataclasses.replace(
            base.layout, invariants=(("m", "sign"), ("step", "step"),
                                     ("sign", "sign"))))
    with pytest.raises(AssertionError, match="finite"):
        validate_program(wrong_head)

    with pytest.raises(ValueError, match="unknown plane field"):
        StateLayout(plane_fields=("m",), packing=(("m", None),),
                    invariants=(("step", "finite"),))
    with pytest.raises(ValueError, match="not one of"):
        StateLayout(plane_fields=("m",), packing=(("m", None),),
                    invariants=(("m", "positive"),))
    with pytest.raises(ValueError, match="duplicate"):
        StateLayout(plane_fields=("m",), packing=(("m", None),),
                    invariants=(("m", "finite"), ("m", "finite")))


def test_every_registered_program_declares_full_invariants():
    """Pin the registry-wide guarantee check_health depends on: every
    registered family's every plane field carries a domain declaration."""
    from repro.core import program as program_mod

    for fam in program_mod.registered_families():
        layout = program_mod.family_base(fam).layout
        declared = dict(layout.invariants)
        assert set(declared) == set(layout.plane_fields), fam
        for head in layout.heads:
            assert declared[head] == "finite", (fam, head)
