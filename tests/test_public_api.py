"""Public-API lint (repro.api.lint): every subpackage `__all__` name must
resolve — export drift (like the near-miss in PR 2's parallel/__init__.py)
fails here AND in the dedicated CI step."""
import pytest

from repro.api.lint import check_public_api, iter_subpackages


def test_every_dunder_all_name_resolves():
    exported = check_public_api()
    # the core layers must actually export things — an empty report would
    # mean the walker silently skipped them
    for pkg in ("repro", "repro.api", "repro.core", "repro.core.baselines",
                "repro.kernels", "repro.parallel", "repro.serve",
                "repro.monitor"):
        assert pkg in exported and exported[pkg], f"{pkg} exports nothing?"


def test_walker_sees_only_packages():
    """Leaf modules (e.g. launch.dryrun sets XLA_FLAGS at import) must not
    be imported by the lint walk."""
    names = [name for name, _ in iter_subpackages()]
    assert "repro.launch.dryrun" not in names
    assert "repro.launch" in names


def test_drift_is_reported_with_package_and_name(monkeypatch):
    import repro.api as api_pkg

    monkeypatch.setattr(api_pkg, "__all__",
                        list(api_pkg.__all__) + ["NotARealExport"])
    with pytest.raises(AssertionError, match="NotARealExport"):
        check_public_api()


def test_facade_names_resolve_from_top_level():
    import repro

    for name in ("QuantileFleet", "FleetSpec", "StreamCursor",
                 "QuantileEstimator", "FrugalEstimator"):
        assert getattr(repro, name) is not None
