"""Statistical behaviour per the paper's analysis (§4) and experiments (§7).

These are deterministic given fixed seeds; thresholds carry slack over the
theory since Thm 1/2 are asymptotic.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    frugal1u_init, frugal1u_process, frugal2u_init, frugal2u_process,
)
from repro.core.reference import relative_mass_error
from repro.data.streams import cauchy_stream, dynamic_cauchy_stream


def _mass_err(est, stream, q):
    return relative_mass_error(float(est), sorted(stream.tolist()), q)


def test_thm1_linear_approach_speed_1u():
    """Thm 1: starting M away, the estimate crosses the quantile vicinity in
    O(M) steps. Uniform integers on [0, 200): median 100, delta ~ 1/200.
    T = M|log eps|/delta with M=100, eps=.05, delta=.005 -> ~6e4. We check the
    estimate has crossed within that budget (it should take ~2*M steps since
    every below-median item drives up with prob ~ 1/2 + delta)."""
    rng = np.random.default_rng(7)
    n = 60_000
    items = rng.integers(0, 200, size=n).astype(np.float32)
    st = frugal1u_init(1)
    st, trace = frugal1u_process(
        st, jnp.asarray(items)[:, None], key=jax.random.PRNGKey(0),
        quantile=0.5, return_trace=True)
    trace = np.asarray(trace)[:, 0]
    first_cross = np.argmax(trace >= 95.0)
    assert trace.max() >= 95.0, "never approached the median"
    assert first_cross < n // 2, f"approach too slow: {first_cross}"


def test_thm2_stability_band_1u():
    """Thm 2: once at the quantile, the estimate stays within a
    O(sqrt(delta log t)) mass band. Uniform ints [0,200): delta=0.005,
    t=30000 -> band ~ 2*sqrt(.005*ln(3e4/.05)) ~ 0.36 in mass. We assert a
    much tighter empirical band of 0.15 mass over the last half (the max
    excursion of the walk varies ~0.07-0.13 across RNG keys for both the
    threefry and the fused counter-hash uniform streams)."""
    rng = np.random.default_rng(8)
    n = 60_000
    items = rng.integers(0, 200, size=n).astype(np.float32)
    st = frugal1u_init(1, init=100.0)  # start at the true median
    st, trace = frugal1u_process(
        st, jnp.asarray(items)[:, None], key=jax.random.PRNGKey(1),
        quantile=0.5, return_trace=True)
    trace = np.asarray(trace)[:, 0][n // 2:]
    sorted_items = sorted(items.tolist())
    errs = [abs(relative_mass_error(m, sorted_items, 0.5)) for m in trace[::500]]
    assert max(errs) < 0.15, f"stability band violated: {max(errs):.3f}"


@pytest.mark.parametrize("q", [0.5, 0.9])
def test_2u_converges_on_cauchy(q):
    """Paper Fig. 4: Frugal-2U reaches the Cauchy quantile from 0 within 3e4
    items despite the quantile being ~1e4 in value."""
    stream = cauchy_stream(30_000, rng=np.random.default_rng(9)).astype(np.float32)
    st = frugal2u_init(1)
    st, _ = frugal2u_process(st, jnp.asarray(stream)[:, None],
                             key=jax.random.PRNGKey(2), quantile=q)
    err = _mass_err(st.m[0], stream, q)
    assert abs(err) < 0.05, f"2U mass error {err:.3f} at q={q}"


def test_2u_faster_than_1u_on_large_quantiles():
    """Paper Figs. 4/8/10: with quantile values ~1e4, 1U (step 1) cannot reach
    in 3e4 steps while 2U can."""
    stream = cauchy_stream(30_000, rng=np.random.default_rng(10)).astype(np.float32)
    s1 = frugal1u_init(1)
    s1, _ = frugal1u_process(s1, jnp.asarray(stream)[:, None],
                             key=jax.random.PRNGKey(3), quantile=0.5)
    s2 = frugal2u_init(1)
    s2, _ = frugal2u_process(s2, jnp.asarray(stream)[:, None],
                             key=jax.random.PRNGKey(3), quantile=0.5)
    e1 = abs(_mass_err(s1.m[0], stream, 0.5))
    e2 = abs(_mass_err(s2.m[0], stream, 0.5))
    assert e2 < e1, f"2U ({e2:.3f}) should beat 1U ({e1:.3f}) here"
    # 1U's ±1 walk covers at most ~T/2 expected distance: it is still short of
    # the 1e4-valued median after 3e4 items, while 2U has converged.
    assert e1 > 0.02, "1U unexpectedly converged — stream too easy for the claim"
    assert e2 < 0.02, f"2U should have converged: {e2:.3f}"


def test_memoryless_adaptation_to_distribution_change():
    """Paper Fig. 5: after the underlying distribution switches, estimates
    chase the NEW quantile (no need to outweigh old data)."""
    stream, segs = dynamic_cauchy_stream(20_000, rng=np.random.default_rng(11))
    stream = stream.astype(np.float32)
    st = frugal2u_init(1)
    st, trace = frugal2u_process(st, jnp.asarray(stream)[:, None],
                                 key=jax.random.PRNGKey(4), quantile=0.5,
                                 return_trace=True)
    trace = np.asarray(trace)[:, 0]
    # end of segment 0 (domain [2e4, 2.5e4]) -> near 22500
    end0 = trace[19_999]
    assert 20_000.0 <= end0 <= 25_000.0
    # end of segment 1 (domain [1e4, 1.5e4]) -> moved DOWN toward 12500
    end1 = trace[39_999]
    assert end1 <= 16_000.0, f"failed to chase the new (lower) median: {end1}"
    # end of segment 2 (domain [1.5e4, 2e4]) -> moved back UP
    end2 = trace[-1]
    assert 14_000.0 <= end2 <= 21_000.0, f"failed to chase the middle median: {end2}"


def test_quantile_generality_multiple_targets():
    """§3.2: one sketch per quantile target; all must land on target mass."""
    rng = np.random.default_rng(12)
    n = 80_000
    items = rng.normal(500.0, 100.0, size=n).astype(np.float32)
    qs = np.asarray([0.1, 0.25, 0.5, 0.75, 0.9], np.float32)
    st = frugal2u_init(5, init=500.0)
    st, _ = frugal2u_process(st, jnp.tile(jnp.asarray(items)[:, None], (1, 5)),
                             key=jax.random.PRNGKey(5), quantile=qs)
    sorted_items = sorted(items.tolist())
    for i, q in enumerate(qs):
        err = relative_mass_error(float(st.m[i]), sorted_items, float(q))
        assert abs(err) < 0.05, f"q={q}: mass error {err:.3f}"
