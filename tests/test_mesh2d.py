"""2-D (data × lane) mesh fleets (parallel.mesh2d + the TopologySpec
surface): chunk→replica assignment keyed off the ABSOLUTE tick, the pinned
deterministic merge rule, elastic resharding, and cross-shape checkpoint
restore. Single-device tier-1 drives the sequential replica loop; the
multi-device CI job re-runs the same contracts over real shard_map meshes
(plus tests/test_fault_tolerance.py's forced-8-device matrix leg, which
pins loop ≡ shard_map bit-for-bit)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import FleetSpec, QuantileFleet, TopologySpec
from repro.core.sketch import GroupedQuantileSketch
from repro.parallel.mesh2d import Mesh2DFleet, merge_replica_planes
from repro.resilience import health as health_mod
from repro.train import checkpoint as ckpt_lib, elastic

G, T, CHUNK = 6, 400, 32
QS = (0.5, 0.9)


def _items(t=T, g=G, seed=4):
    rng = np.random.default_rng(seed)
    return rng.normal(3.0, 2.0, size=(t, g)).astype(np.float32)


def _fleet(topo=None, seed=9, g=G, chunk=CHUNK, program="2u", **kw):
    spec = FleetSpec(num_groups=g, quantiles=QS, chunk_t=chunk,
                     topology=topo, program=program, **kw)
    return QuantileFleet.create(spec, seed=seed)


# --------------------------------------------------------------- TopologySpec
def test_topology_spec_placement_and_validation():
    assert TopologySpec().placement == "single"
    assert TopologySpec(lanes=4).placement == "sharded"
    assert TopologySpec(data=2).placement == "mesh2d"
    assert TopologySpec(data=2, lanes=4).num_devices == 8
    assert TopologySpec() == TopologySpec(data=1, lanes=1)
    with pytest.raises(ValueError):
        TopologySpec(data=0)
    with pytest.raises(ValueError):
        TopologySpec(lanes=-1)
    d = TopologySpec(data=2, lanes=3).describe()
    assert d == {"data": 2, "lanes": 3, "placement": "mesh2d"}


def test_topology_resolve_single_device_falls_back_to_loop():
    topo = TopologySpec(data=2, lanes=2).resolve()
    n_dev = len(jax.devices())
    if n_dev >= 4:
        assert topo.on_devices and topo.mesh2d().devices.shape == (2, 2)
    else:
        assert not topo.on_devices
        with pytest.raises(ValueError):
            topo.mesh2d()


# ------------------------------------------------- replica trajectory pinning
def test_replica_state_is_single_fleet_over_its_chunk_shard():
    """replica(c) = c mod R on the absolute chunk index: replica r's state
    must be bit-identical to a SINGLE-device fleet that ingested exactly
    r's chunks at their true absolute tick offsets — the 2-D bit-exactness
    anchor (DESIGN.md §15)."""
    items = _items()
    fl = _fleet(TopologySpec(data=2)).ingest(items)
    m2 = fl.state
    assert isinstance(m2, Mesh2DFleet)
    planes = m2.replica_planes()
    for r in range(2):
        single = _fleet()
        cur = single.cursor
        sk = single.state
        for c in range(-(-T // CHUNK)):
            if c % 2 != r:
                continue
            block = items[c * CHUNK:(c + 1) * CHUNK]
            from repro.core import streaming
            sk = streaming.ingest_array(
                sk, block, seed=int(cur.seed), chunk_t=CHUNK,
                t_offset=c * CHUNK, g_offset=0,
                lanes_per_group=len(QS))
        for f, p in zip(sk.program.layout.plane_fields, planes):
            np.testing.assert_array_equal(
                np.asarray(getattr(sk, f)), p[r],
                err_msg=f"replica {r} plane {f!r} != its sub-stream")


def test_split_invariance_at_arbitrary_call_boundaries():
    """Mid-chunk call splits NaN-pad both sides of the cut, so every item
    lands on the same replica at the same tick regardless of batching."""
    items = _items()
    base = _fleet(TopologySpec(data=3))
    one = base.ingest(items)
    for cut in (1, 137, 320):
        two = base.ingest(items[:cut]).ingest(items[cut:])
        for a, b in zip(one.state.replica_planes(),
                        two.state.replica_planes()):
            np.testing.assert_array_equal(a, b, err_msg=f"cut={cut}")
    streamed = base.ingest_stream([items[:50], items[50:211], items[211:]])
    for a, b in zip(one.state.replica_planes(),
                    streamed.state.replica_planes()):
        np.testing.assert_array_equal(a, b)


def test_estimates_invariant_to_lane_shard_count_at_fixed_replicas():
    items = _items()
    ref = _fleet(TopologySpec(data=2, lanes=1)).ingest(items).estimate()
    for lanes in (2, 3, 4):
        got = _fleet(TopologySpec(data=2, lanes=lanes)).ingest(items)
        np.testing.assert_array_equal(ref, got.estimate())


# ----------------------------------------------------------- pinned merge rule
def test_merge_rule_folds_by_invariant_domain():
    """finite → fixed-order running mean; step → elementwise max; sign →
    replica 0 (all IEEE-exact f32 elementwise, so host/numpy == device)."""
    from repro.core import program as program_mod

    prog = program_mod.family_base("2u")
    rng = np.random.default_rng(1)
    m = rng.normal(size=(3, 5)).astype(np.float32)
    step = rng.integers(1, 100, (3, 5)).astype(np.float32)
    sign = rng.choice([-1.0, 1.0], (3, 5)).astype(np.float32)
    got = merge_replica_planes(prog, (m, step, sign))
    acc = m[0]
    for r in (1, 2):
        acc = acc + (m[r] - acc) / np.float32(r + 1)
    np.testing.assert_array_equal(got[0], acc)
    np.testing.assert_array_equal(got[1], np.max(step, axis=0))
    np.testing.assert_array_equal(got[2], sign[0])
    # jnp produces the same bits
    got_j = merge_replica_planes(prog, tuple(jnp.asarray(p)
                                             for p in (m, step, sign)),
                                 xp=jnp)
    for a, b in zip(got, got_j):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_merge_of_equal_replicas_is_identity_and_r1_is_identity():
    from repro.core import program as program_mod

    prog = program_mod.family_base("2u")
    rng = np.random.default_rng(2)
    planes = (rng.normal(size=(5,)).astype(np.float32),
              rng.integers(1, 50, (5,)).astype(np.float32),
              rng.choice([-1.0, 1.0], (5,)).astype(np.float32))
    eq = tuple(np.broadcast_to(p, (4,) + p.shape) for p in planes)
    for a, b in zip(merge_replica_planes(prog, eq), planes):
        np.testing.assert_array_equal(a, b)
    r1 = tuple(p[None] for p in planes)
    for a, b in zip(merge_replica_planes(prog, r1), planes):
        np.testing.assert_array_equal(a, b)


def test_merged_state_satisfies_program_invariants():
    """The fold must land INSIDE every declared invariant domain: finite
    heads stay finite, step words stay pack-round-trippable (max of valid
    steps is a valid step), signs stay exact ±1 — so health scans and
    packed checkpoints accept merged state."""
    items = _items()
    for program in ("1u", "2u", "2u-window"):
        fl = _fleet(TopologySpec(data=3), program=program).ingest(items)
        sk = fl._lane_sketch()
        prog = fl.spec.program
        mask = health_mod.validate_planes(prog, sk.planes())
        assert not bool(np.any(np.asarray(mask))), program
        rt = GroupedQuantileSketch.from_packed(sk.packed())
        for f in prog.layout.plane_fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(sk, f)), np.asarray(getattr(rt, f)),
                err_msg=f"{program}: merged {f!r} not pack-round-trippable")


def test_sync_is_idempotent_and_estimate_preserving():
    fl = _fleet(TopologySpec(data=2, lanes=2)).ingest(_items())
    synced = fl.sync()
    np.testing.assert_array_equal(fl.estimate(), synced.estimate())
    again = synced.sync()
    for a, b in zip(synced.state.replica_planes(),
                    again.state.replica_planes()):
        np.testing.assert_array_equal(a, b)
    # after sync every replica holds the canonical state
    planes = synced.state.replica_planes()
    for p in planes:
        for r in range(1, p.shape[0]):
            np.testing.assert_array_equal(p[0], p[r])


# ------------------------------------------------------------------- elastic
def test_reshard_matrix_preserves_or_syncs():
    """(1×1) → (2×1) → (2×2) → (4×1) → (1×1): same-R reshard carries every
    replica bit-for-bit; R-changing reshard passes through the pinned merge
    (estimate invariant); the cursor never moves."""
    items = _items()
    fl = _fleet().ingest(items[:200])
    est = fl.estimate()
    t0 = int(fl.cursor.t_offset)

    fl2 = fl.reshard(TopologySpec(data=2))          # 1 -> 2 replicas
    assert isinstance(fl2.state, Mesh2DFleet)
    np.testing.assert_array_equal(fl2.estimate(), est)
    assert int(fl2.cursor.t_offset) == t0

    fl2 = fl2.ingest(items[200:])                   # replicas diverge
    est2 = fl2.estimate()
    fl22 = fl2.reshard(TopologySpec(data=2, lanes=2))   # same R: bit-exact
    for a, b in zip(fl2.state.replica_planes(),
                    fl22.state.replica_planes()):
        np.testing.assert_array_equal(a, b)

    fl41 = fl22.reshard(TopologySpec(data=4))       # R change: sync point
    np.testing.assert_array_equal(fl41.estimate(), est2)
    back = fl41.reshard(TopologySpec())             # collapse to single
    assert isinstance(back.state, GroupedQuantileSketch)
    assert back.spec.backend == "fused"
    np.testing.assert_array_equal(back.estimate(), est2)

    # post-reshard ingest stays deterministic and placement-consistent:
    # (2×1) and (2×2) fleets continue identically
    more = _items(100, seed=77)
    np.testing.assert_array_equal(fl2.ingest(more).estimate(),
                                  fl22.ingest(more).estimate())


def test_grow_mid_stream_keeps_existing_lanes_bit_for_bit():
    items = _items()
    fl = _fleet(TopologySpec(data=2, lanes=2)).ingest(items[:200])
    before = fl.state.replica_planes()
    grown = fl.grow_groups(G + 3)
    after = grown.state.replica_planes()
    L_old = G * len(QS)
    for a, b in zip(after, before):
        np.testing.assert_array_equal(a[:, :L_old], b)
    # new lanes tick like lanes created at the current cursor: growth is
    # equivalent to a wider fleet whose extra groups saw NaN (no-op) rows
    wide = _fleet(TopologySpec(data=2, lanes=2), g=G + 3)
    pad = np.full((200, 3), np.nan, np.float32)
    wide = wide.ingest(np.concatenate([items[:200], pad], axis=1))
    more = _items(100, g=G + 3, seed=5)
    np.testing.assert_array_equal(grown.ingest(more).estimate(),
                                  wide.ingest(more).estimate())


def test_from_replica_planes_rejects_replica_count_change():
    fl = _fleet(TopologySpec(data=2)).ingest(_items(64))
    m2 = fl.state
    quantile = np.asarray(jax.device_get(m2.sketch.quantile))[:, :G * 2]
    with pytest.raises(ValueError, match="sync point"):
        Mesh2DFleet.from_replica_planes(
            m2.sketch, m2.replica_planes(), quantile,
            TopologySpec(data=3), lanes_per_group=len(QS))


# ---------------------------------------------------------------- checkpoints
def test_checkpoint_records_topology_stanza_and_restores_cross_shape(
        tmp_path):
    items = _items()
    fl = _fleet(TopologySpec(data=2, lanes=2)).ingest(items)
    d = str(tmp_path)
    fl.checkpoint(d, step=3)
    man = ckpt_lib.read_manifest(d)
    assert man["topology"] == {"data": 2, "lanes": 2,
                               "placement": "mesh2d"}
    assert man["format"] == 4
    canon = fl._lane_sketch()
    for topo in (TopologySpec(), TopologySpec(data=4),
                 TopologySpec(data=3, lanes=2)):
        rs = elastic.fleet_reshard_restore(d, fl.spec, topo)
        rsk = rs._lane_sketch()
        for f in fl.spec.program.layout.plane_fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(canon, f)), np.asarray(getattr(rsk, f)),
                err_msg=f"plane {f!r} restored onto {topo}")
        np.testing.assert_array_equal(fl.estimate(), rs.estimate())
        assert int(rs.cursor.t_offset) == int(fl.cursor.t_offset)


def test_single_placement_checkpoint_stanza(tmp_path):
    fl = _fleet().ingest(_items(64))
    fl.checkpoint(str(tmp_path), step=1)
    man = ckpt_lib.read_manifest(str(tmp_path))
    assert man["topology"]["placement"] == "single"


# ------------------------------------------------------------------- facade
def test_mesh2d_fleet_properties_and_event_mode_guard():
    fl = _fleet(TopologySpec(data=2, lanes=2))
    st = fl.state
    assert st.data_replicas == 2
    assert st.memory_words() == 2         # per lane per replica
    assert fl.memory_words() == 2
    n_dev = len(jax.devices())
    assert st.mode == ("shard_map" if n_dev >= 4 else "loop")
    with pytest.raises(NotImplementedError, match="meshed"):
        fl.tick_lanes(np.zeros(fl.num_lanes, np.float32))
    with pytest.raises(NotImplementedError, match="meshed"):
        fl.tick_lanes_sparse(jnp.asarray([0]), jnp.asarray([1.0]))


def test_quarantine_heals_on_2d_placement():
    """Corrupt a merged read path lane: check_health under the 2-D
    placement scans the MERGED canonical lanes and re-places the healed
    sketch (a sync point) — the fleet comes back healthy."""
    fl = _fleet(TopologySpec(data=2), health="quarantine").ingest(_items())
    bad_sk = fl.state.sketch
    m = np.asarray(jax.device_get(bad_sk.m)).copy()
    m[0, 1] = np.nan                       # corrupt one replica's lane
    bad = dataclasses.replace(
        fl, state=dataclasses.replace(fl.state,
                                      sketch=dataclasses.replace(
                                          bad_sk, m=jnp.asarray(m))))
    healed, rep = bad.check_health()
    assert not rep.healthy and rep.quarantined
    ok, rep2 = healed.check_health()
    assert rep2.healthy
    assert isinstance(healed.state, Mesh2DFleet)
