"""Sharded group fleet (parallel/group_sharding): the spec is bit-exactness.

PR 1's counter RNG keys uniforms on the ABSOLUTE (seed, tick, group) triple,
so a fleet sharded over any mesh must reproduce the single-device trajectory
bit-for-bit — any mesh shape, any chunking, any ragged-G padding. The
single-device tests here pin the g_offset plumbing (a shard is just a column
slice ingested at its global offset); the multi-device tests run wherever
>= 2 devices exist (the multi-device CI job forces 8 host devices via
XLA_FLAGS) plus a subprocess proof that runs everywhere.
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import GroupedQuantileSketch, ingest_array, ingest_stream
from repro.parallel import ShardedGroupFleet, group_mesh
from repro.train.checkpoint import restore_checkpoint, save_checkpoint

# Only the property tests need hypothesis; a missing dev dep must not kill
# collection under -x.
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

N_DEV = len(jax.devices())
multidevice = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >= 2 devices — run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the multi-device CI job does)")


def _items(t, g, seed=0, domain=800):
    rng = np.random.default_rng(seed)
    return rng.integers(0, domain, (t, g)).astype(np.float32)


# --------------------------------------------------- g_offset core invariant
@pytest.mark.parametrize("algo", ["1u", "2u"])
def test_g_offset_column_slices_reproduce_full_run(algo):
    """A shard IS a column slice ingested at its global offset: ingesting
    columns [a:b] with g_offset=a must equal the slice of the full run."""
    t, g = 300, 29
    items = _items(t, g, seed=1)
    key = jax.random.PRNGKey(3)
    full = GroupedQuantileSketch.create(g, quantile=0.7, algo=algo) \
        .process(jnp.asarray(items), key)
    for a, b in ((0, 7), (7, 20), (20, 29)):
        part = GroupedQuantileSketch.create(b - a, quantile=0.7, algo=algo)
        part = ingest_array(part, items[:, a:b], key, chunk_t=64, g_offset=a)
        np.testing.assert_array_equal(np.asarray(full.m[a:b]),
                                      np.asarray(part.m))
        if algo == "2u":
            np.testing.assert_array_equal(np.asarray(full.step[a:b]),
                                          np.asarray(part.step))
            np.testing.assert_array_equal(np.asarray(full.sign[a:b]),
                                          np.asarray(part.sign))


def test_g_offset_stream_matches_array():
    t, g = 257, 11
    items = _items(t, g, seed=2)
    key = jax.random.PRNGKey(8)
    sk = GroupedQuantileSketch.create(g, quantile=0.25, algo="2u")
    a = ingest_array(sk, items, key, chunk_t=100, g_offset=5)
    b = ingest_stream(sk, [items[:40], items[40:]], key, chunk_t=100,
                      g_offset=5)
    np.testing.assert_array_equal(np.asarray(a.m), np.asarray(b.m))
    np.testing.assert_array_equal(np.asarray(a.step), np.asarray(b.step))


# ------------------------------------------------------------ 1-device mesh
@pytest.mark.parametrize("algo", ["1u", "2u"])
def test_one_device_fleet_bit_identical(algo):
    t, g = 500, 37
    items = _items(t, g, seed=3)
    key = jax.random.PRNGKey(9)
    base = GroupedQuantileSketch.create(g, quantile=0.9, algo=algo) \
        .process(jnp.asarray(items), key)
    fleet = ShardedGroupFleet.create(g, quantile=0.9, algo=algo,
                                     mesh=group_mesh(1))
    fa = fleet.ingest_array(items, key, chunk_t=128)
    np.testing.assert_array_equal(np.asarray(base.m), fa.estimate())
    fs = fleet.ingest_stream([items[:123], items[123:]], key, chunk_t=99)
    np.testing.assert_array_equal(np.asarray(base.m), fs.estimate())
    if algo == "2u":
        un = fa.unshard()
        np.testing.assert_array_equal(np.asarray(base.step),
                                      np.asarray(un.step))
        np.testing.assert_array_equal(np.asarray(base.sign),
                                      np.asarray(un.sign))


def test_fleet_packed_checkpoint_roundtrip(tmp_path):
    g = 48
    items = _items(200, g, seed=4)
    key = jax.random.PRNGKey(1)
    fleet = ShardedGroupFleet.create(g, quantile=0.5, algo="2u",
                                     mesh=group_mesh(1))
    fleet = fleet.ingest_array(items, key, chunk_t=64)
    save_checkpoint(str(tmp_path), 3, fleet.packed())
    like = ShardedGroupFleet.create(g, quantile=0.5, algo="2u",
                                    mesh=group_mesh(1)).packed()
    restored, step = restore_checkpoint(str(tmp_path), like=like)
    f2 = ShardedGroupFleet.from_packed(restored, mesh=group_mesh(1))
    np.testing.assert_array_equal(fleet.estimate(), f2.estimate())
    # trajectories continue identically after restore
    more = _items(100, g, seed=5)
    k2 = jax.random.PRNGKey(2)
    np.testing.assert_array_equal(
        fleet.ingest_array(more, k2, chunk_t=64).estimate(),
        f2.ingest_array(more, k2, chunk_t=64).estimate())


def test_t_offset_continuation_matches_one_shot():
    """Continuing a stream across calls with a running t_offset must equal
    one uninterrupted ingest — on the fleet AND the unsharded stream path
    (without it, a same-seed second call would replay the first call's
    uniforms)."""
    t, g = 400, 13
    items = _items(t, g, seed=9)
    key = jax.random.PRNGKey(6)
    base = GroupedQuantileSketch.create(g, quantile=0.5, algo="2u") \
        .process(jnp.asarray(items), key)

    fleet = ShardedGroupFleet.create(g, quantile=0.5, algo="2u",
                                     mesh=group_mesh(1))
    fleet = fleet.ingest_array(items[:150], key, chunk_t=64)
    fleet = fleet.ingest_array(items[150:], key, chunk_t=64, t_offset=150)
    np.testing.assert_array_equal(np.asarray(base.m), fleet.estimate())

    fleet2 = ShardedGroupFleet.create(g, quantile=0.5, algo="2u",
                                      mesh=group_mesh(1))
    fleet2 = fleet2.ingest_stream([items[:70]], key, chunk_t=64)
    fleet2 = fleet2.ingest_stream([items[70:]], key, chunk_t=64, t_offset=70)
    np.testing.assert_array_equal(np.asarray(base.m), fleet2.estimate())

    sk = GroupedQuantileSketch.create(g, quantile=0.5, algo="2u")
    sk = ingest_stream(sk, [items[:70]], key, chunk_t=64)
    sk = ingest_stream(sk, [items[70:]], key, chunk_t=64, t_offset=70)
    np.testing.assert_array_equal(np.asarray(base.m), np.asarray(sk.m))


def test_fleet_accepts_preplaced_padded_items():
    """_pad_items is idempotent: benchmark-style pre-placed [T, Gp] arrays
    re-ingest without re-validation errors, bit-identically — on a >= 2-way
    mesh (the multi-device CI job) this exercises ragged G with Gp > G."""
    t, g = 200, 13
    items = _items(t, g, seed=10)
    key = jax.random.PRNGKey(7)
    fleet = ShardedGroupFleet.create(g, quantile=0.5, algo="2u",
                                     mesh=group_mesh(2 if N_DEV >= 2 else 1))
    placed = fleet._pad_items(items)
    a = fleet.ingest_array(items, key, chunk_t=64)
    b = fleet.ingest_array(placed, key, chunk_t=64)
    np.testing.assert_array_equal(a.estimate(), b.estimate())


def test_fleet_rejects_bad_item_shapes():
    fleet = ShardedGroupFleet.create(8, mesh=group_mesh(1))
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError):
        fleet.ingest_array(np.zeros((10, 5), np.float32), key)
    with pytest.raises(ValueError):
        fleet.ingest_array(np.zeros((10, 8), np.float32), key, chunk_t=0)


# ------------------------------------------------------------- multi-device
def _mesh_sizes():
    return [n for n in (2, 4, 8) if n <= N_DEV]


# (The 2/4/8-way mesh x chunking x ragged-G bit-exactness sweep for every
# registered program — 1U and 2U included — is owned by the shared harness
# in tests/conftest.py, driven from test_fleet_api.py; this file keeps the
# direct ShardedGroupFleet API surfaces, the hypothesis property, and the
# subprocess proof.)


@multidevice
def test_slo_fleet_sharded_restore(tmp_path):
    """SLOFleet checkpoints re-place onto a group mesh via restore's
    shardings path (the Frugal2UState node maps through the packed-sharding
    translation in train/checkpoint.py)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.frugal import Frugal2UState
    from repro.serve import SLOFleet

    fleet = SLOFleet(seed=3)   # capacity 64 x 3 lanes = 192, divides 2/4/8
    rng = np.random.default_rng(1)
    for i in range(200):
        fleet.observe(f"r{i % 5}", "tok_q50_ms", float(rng.lognormal(3, .4)))
    save_checkpoint(str(tmp_path), 1, fleet.checkpoint_state())
    mesh = group_mesh(N_DEV)
    sh = NamedSharding(mesh, jax.sharding.PartitionSpec("groups"))
    shardings = {"sketch": Frugal2UState(m=sh, step=sh, sign=sh),
                 "ticks": sh, "meta_blob": NamedSharding(mesh, P())}
    state, _ = restore_checkpoint(str(tmp_path),
                                  like=fleet.checkpoint_template(),
                                  shardings=shardings)
    restored = SLOFleet.from_checkpoint_state(state)
    assert restored.summaries() == fleet.summaries()
    for f in (fleet, restored):
        f.observe("r1", "tok_q50_ms", 25.0)
    assert fleet.estimate("r1", "tok_q50_ms") \
        == restored.estimate("r1", "tok_q50_ms")


@multidevice
def test_sharded_restore_onto_mesh():
    """Elastic path: a fleet saved from one mesh restores onto another via
    state_shardings (G divisible) and from_packed (any G)."""
    g = 64 * N_DEV
    items = _items(150, g, seed=7)
    key = jax.random.PRNGKey(5)
    fleet = ShardedGroupFleet.create(g, mesh=group_mesh(N_DEV))
    fleet = fleet.ingest_array(items, key, chunk_t=64)
    sh = fleet.state_shardings()
    assert sh.m.spec == jax.sharding.PartitionSpec("groups")
    small = ShardedGroupFleet.from_packed(fleet.packed(), mesh=group_mesh(2))
    np.testing.assert_array_equal(fleet.estimate(), small.estimate())


if HAS_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(
        g=st.integers(1, 23),
        t=st.integers(1, 120),
        chunk_t=st.integers(1, 64),
        n_idx=st.integers(0, 3),
        cut=st.integers(0, 119),
        algo=st.sampled_from(["1u", "2u"]),
    )
    def test_property_any_mesh_and_chunking_is_bit_exact(
            g, t, chunk_t, n_idx, cut, algo):
        """Hypothesis sweep of the whole contract: ANY mesh size (from the
        devices available) × ANY chunk_t × ANY producer slicing × ragged G
        reproduces the unsharded one-shot trajectory bit-for-bit."""
        n = [d for d in (1, 2, 4, 8) if d <= N_DEV][
            n_idx % len([d for d in (1, 2, 4, 8) if d <= N_DEV])]
        items = _items(t, g, seed=g * 131 + t)
        key = jax.random.PRNGKey(g + 7 * t)
        base = GroupedQuantileSketch.create(g, quantile=0.5, algo=algo) \
            .process(jnp.asarray(items), key)
        fleet = ShardedGroupFleet.create(g, quantile=0.5, algo=algo,
                                         mesh=group_mesh(n))
        cut = min(cut, t)
        pieces = [items[:cut], items[cut:]] if 0 < cut < t else [items]
        fs = fleet.ingest_stream(pieces, key, chunk_t=chunk_t)
        np.testing.assert_array_equal(np.asarray(base.m), fs.estimate())
        fa = fleet.ingest_array(items, key, chunk_t=chunk_t)
        np.testing.assert_array_equal(np.asarray(base.m), fa.estimate())

else:

    def test_property_tests_need_hypothesis():
        pytest.skip("hypothesis not installed — property tests not collected "
                    "(pip install -r requirements-dev.txt)")


# ------------------------------------------------- subprocess proof (slow)
_SUBPROC_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import GroupedQuantileSketch
from repro.parallel import ShardedGroupFleet, group_mesh
assert len(jax.devices()) == 8, jax.devices()
items = np.random.default_rng(0).integers(0, 500, (300, 21)).astype(np.float32)
key = jax.random.PRNGKey(2)
base = GroupedQuantileSketch.create(21, quantile=0.9, algo="2u").process(
    jnp.asarray(items), key)
fleet = ShardedGroupFleet.create(21, quantile=0.9, algo="2u",
                                 mesh=group_mesh(8))
out = fleet.ingest_array(items, key, chunk_t=64)
np.testing.assert_array_equal(np.asarray(base.m), out.estimate())
un = out.unshard()
np.testing.assert_array_equal(np.asarray(base.step), np.asarray(un.step))
print("SHARDED-OK")
"""


@pytest.mark.slow
def test_eight_device_subprocess_bit_exact():
    """Runs the 8-way sharding proof in a child process so it works even
    when this pytest process initialized jax with one device."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", "")).strip()
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", _SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "SHARDED-OK" in res.stdout
