"""Benchmark data must be identical across fresh interpreter processes.

E3's generator seed once came from `hash(kind)`, which Python salts
per-process (PYTHONHASHSEED) — the "same" benchmark run produced different
stream data every invocation. The seed now derives from zlib.crc32; this
pins it by hashing the generated data in two subprocesses launched with
DIFFERENT explicit hash seeds (the adversarial case for the old bug).
"""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")

_SNIPPET = (
    "from benchmarks.bench_groupby_tcp import stream_data_digest;"
    "print(stream_data_digest())"
)


def _digest_in_fresh_process(hash_seed: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join([SRC, ROOT])
    env["PYTHONHASHSEED"] = hash_seed
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run([sys.executable, "-c", _SNIPPET], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout.strip()


def test_tcp_stream_data_identical_across_processes():
    d1 = _digest_in_fresh_process("0")
    d2 = _digest_in_fresh_process("12345")
    assert d1 == d2, (
        "stream data depends on the per-process hash salt again "
        f"({d1} != {d2})")
