# NOTE: do NOT set XLA_FLAGS / host device count here. Smoke tests and
# benchmarks must see the single real CPU device; only launch/dryrun.py
# forces 512 placeholder devices (in its own process).
import os
import sys

# Make `src/` importable without installation (PYTHONPATH=src also works).
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "kernel: Pallas kernel validation tests")
    config.addinivalue_line("markers", "slow: long-running subprocess tests")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# --------------------------------------------------------------------------
# Shared LaneProgram bit-exactness harness.
#
# ONE parametrized sweep replaces the copy-pasted backend × chunking × mesh
# loops that used to live in test_drift / test_fleet_api /
# test_group_sharding: the `lane_program` fixture enumerates EVERY family
# registered in core.program (canonical small-parameter instances), so a
# newly registered rule gets its cross-backend coverage for free — no test
# edits. The harness compares the ESTIMATES and the FULL persistent plane
# state (every layout field, gathered/unsharded) bit-for-bit, across:
#   * backend jnp (pure scan), fused (program kernel, two chunk sizes, a
#     split ingest + a re-chunked stream ingest), sharded (each requested
#     mesh size, ragged lane counts included);
#   * a multi-quantile (Q=2) lane plane, so lane fan-out is covered too.
# --------------------------------------------------------------------------
# Enumerating the registry imports repro.core.program (and therefore jax)
# at collection time — the same cost every test module in this suite
# already pays by importing jax at module level; the payoff is that a
# newly registered family appears as a test id with zero test edits.
def _all_program_instances():
    from repro.core import program as program_mod

    return program_mod.test_instances()


@pytest.fixture(params=_all_program_instances(),
                ids=lambda p: p.family)
def lane_program(request):
    """Every registered LaneProgram family, one canonical instance each."""
    return request.param


def run_program_invariance_sweep(program, mesh_sizes=(1,), g=5,
                                 quantiles=(0.5, 0.9), t=400, seed=9,
                                 data_seed=4):
    """Assert `program` is bit-exact across backend × chunking × mesh.

    Builds one fleet per (backend, chunk_t, mesh) configuration, ingests the
    same [t, g] stream split across ingest()/ingest_stream() calls, and
    requires identical estimates AND identical full plane state everywhere.
    Returns the reference estimate plane for optional further checks.
    """
    import jax
    from repro.api import FleetSpec, QuantileFleet, TopologySpec

    items = np.random.default_rng(data_seed).integers(
        0, 800, (t, g)).astype(np.float32)
    n_dev = len(jax.devices())
    configs = [("jnp", 4096, None), ("fused", 64, None), ("fused", 333, None)]
    for n in mesh_sizes:
        if n <= n_dev:
            configs.append(("fused", 100, TopologySpec(lanes=n)))

    plane_fields = program.layout.plane_fields
    ref_est = ref_state = ref_cfg = None
    for backend, chunk, topo in configs:
        spec = FleetSpec(num_groups=g, quantiles=quantiles, backend=backend,
                         chunk_t=chunk, topology=topo, program=program)
        fl = QuantileFleet.create(spec, seed=seed)
        cut = max(1, t // 3)
        fl = fl.ingest(items[:cut]).ingest_stream([items[cut:cut + 51],
                                                   items[cut + 51:]])
        est = fl.estimate()
        sk = fl._lane_sketch()
        state = {f: np.asarray(getattr(sk, f)) for f in plane_fields}
        if ref_est is None:
            ref_est, ref_state, ref_cfg = est, state, (backend, chunk)
            continue
        np.testing.assert_array_equal(
            ref_est, est,
            err_msg=f"{program.family}: estimates diverge between "
                    f"{ref_cfg} and ({backend}, {chunk})")
        for f in plane_fields:
            np.testing.assert_array_equal(
                ref_state[f], state[f],
                err_msg=f"{program.family}: plane {f!r} diverges between "
                        f"{ref_cfg} and ({backend}, {chunk})")

    # ---- cross-topology checkpoint restore phase ----------------------
    # Save under a 2-D (2 × 1) topology, restore under single-device, a
    # different replica count, and (devices allowing) a 1-D lane mesh: the
    # payload is the merged canonical lane state (a checkpoint is a sync
    # point — DESIGN.md §15), so every restored placement must carry
    # identical plane bits, an identical cursor, and replay identical
    # releases — including the 2u-dp family, whose Laplace noise keys
    # deterministically on (seed, cursor, lane).
    import tempfile
    from repro.train import elastic

    save_spec = FleetSpec(num_groups=g, quantiles=quantiles, chunk_t=64,
                          program=program,
                          topology=TopologySpec(data=2))
    fl2 = QuantileFleet.create(save_spec, seed=seed)
    fl2 = fl2.ingest(items[:cut]).ingest(items[cut:])
    canon = fl2._lane_sketch()
    restore_topos = [TopologySpec(), TopologySpec(data=3)]
    restore_topos += [TopologySpec(lanes=n) for n in mesh_sizes
                      if 1 < n <= n_dev]
    if n_dev >= 2:
        restore_topos.append(TopologySpec(data=2, lanes=2))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        fl2.checkpoint(ckpt_dir, step=1)
        for topo in restore_topos:
            rs = elastic.fleet_reshard_restore(ckpt_dir, save_spec, topo)
            rsk = rs._lane_sketch()
            for f in plane_fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(canon, f)),
                    np.asarray(getattr(rsk, f)),
                    err_msg=f"{program.family}: plane {f!r} not "
                            f"bit-identical restored onto {topo}")
            np.testing.assert_array_equal(
                np.asarray(fl2.cursor.t_offset),
                np.asarray(rs.cursor.t_offset),
                err_msg=f"{program.family}: cursor diverges restored "
                        f"onto {topo}")
            np.testing.assert_array_equal(
                fl2.estimate(), rs.estimate(),
                err_msg=f"{program.family}: release replay diverges "
                        f"restored onto {topo}")

    # ---- sparse event-round phase -------------------------------------
    # Event mode must be bit-exact too: dense `tick_lanes` rounds vs the
    # sparse gather→tick→scatter path (jnp, jnp+donation, and the Pallas
    # scatter kernel in interpret mode), same counter uniforms keyed on
    # absolute lane id + per-lane tick. Three fleets are created (NOT
    # aliased) because the donated leg invalidates its own buffers.
    import jax.numpy as jnp
    from repro.kernels import ops as kernel_ops

    ev_spec = FleetSpec(num_groups=g, quantiles=quantiles, backend="fused",
                        program=program)
    L = ev_spec.num_lanes
    fl_dense = QuantileFleet.create(ev_spec, seed=seed, per_lane_clock=True)
    fl_sp = QuantileFleet.create(ev_spec, seed=seed, per_lane_clock=True)
    fl_dn = QuantileFleet.create(ev_spec, seed=seed, per_lane_clock=True)
    sk0 = fl_dense._lane_sketch()
    pal_planes = tuple(jnp.asarray(p) for p in sk0.planes())
    pal_ticks = jnp.zeros((L,), jnp.int32)
    ev_rng = np.random.default_rng(data_seed + 1)
    for r in range(5):
        k = int(ev_rng.integers(1, L + 1))
        lanes = np.sort(ev_rng.choice(L, size=k, replace=False)) \
            .astype(np.int32)
        vals = ev_rng.integers(0, 800, k).astype(np.float32)
        mask = np.ones(k, np.int32)
        if r == 2 and k < L:   # cover a masked-out pad slot
            pad = next(i for i in range(L) if i not in set(lanes.tolist()))
            lanes = np.append(lanes, np.int32(pad))
            vals = np.append(vals, np.float32(np.nan))
            mask = np.append(mask, np.int32(0))
        dense_items = np.full(L, np.nan, np.float32)
        dense_items[lanes[mask == 1]] = vals[mask == 1]
        fl_dense = fl_dense.tick_lanes(dense_items,
                                       (~np.isnan(dense_items)).astype(
                                           np.int32))
        fl_sp = fl_sp.tick_lanes_sparse(lanes, vals, mask)
        fl_dn = fl_dn.tick_lanes_sparse(lanes, vals, mask, donate=True)
        pal_planes, pal_ticks = kernel_ops.frugal_update_sparse(
            lanes, vals, mask, pal_planes, pal_ticks, sk0.quantile,
            fl_dense.cursor.seed, fl_dense._scalars(), program=program,
            interpret=True)
    ref = fl_dense._lane_sketch()
    for tag, fl in (("sparse-jnp", fl_sp), ("sparse-donated", fl_dn)):
        np.testing.assert_array_equal(
            fl_dense.estimate(), fl.estimate(),
            err_msg=f"{program.family}: {tag} estimates diverge from dense")
        sk = fl._lane_sketch()
        for f in plane_fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, f)), np.asarray(getattr(sk, f)),
                err_msg=f"{program.family}: {tag} plane {f!r} diverges")
        np.testing.assert_array_equal(
            np.asarray(fl_dense.cursor.t_offset),
            np.asarray(fl.cursor.t_offset),
            err_msg=f"{program.family}: {tag} lane clocks diverge")
    for f, p in zip(plane_fields, pal_planes):
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, f)), np.asarray(p),
            err_msg=f"{program.family}: pallas scatter plane {f!r} diverges")
    np.testing.assert_array_equal(
        np.asarray(fl_dense.cursor.t_offset), np.asarray(pal_ticks),
        err_msg=f"{program.family}: pallas scatter lane clocks diverge")
    return ref_est


@pytest.fixture
def program_sweep():
    """The shared harness as a fixture (callable) for test modules."""
    return run_program_invariance_sweep
