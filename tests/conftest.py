# NOTE: do NOT set XLA_FLAGS / host device count here. Smoke tests and
# benchmarks must see the single real CPU device; only launch/dryrun.py
# forces 512 placeholder devices (in its own process).
import os
import sys

# Make `src/` importable without installation (PYTHONPATH=src also works).
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "kernel: Pallas kernel validation tests")
    config.addinivalue_line("markers", "slow: long-running subprocess tests")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# --------------------------------------------------------------------------
# Shared LaneProgram bit-exactness harness.
#
# ONE parametrized sweep replaces the copy-pasted backend × chunking × mesh
# loops that used to live in test_drift / test_fleet_api /
# test_group_sharding: the `lane_program` fixture enumerates EVERY family
# registered in core.program (canonical small-parameter instances), so a
# newly registered rule gets its cross-backend coverage for free — no test
# edits. The harness compares the ESTIMATES and the FULL persistent plane
# state (every layout field, gathered/unsharded) bit-for-bit, across:
#   * backend jnp (pure scan), fused (program kernel, two chunk sizes, a
#     split ingest + a re-chunked stream ingest), sharded (each requested
#     mesh size, ragged lane counts included);
#   * a multi-quantile (Q=2) lane plane, so lane fan-out is covered too.
# --------------------------------------------------------------------------
# Enumerating the registry imports repro.core.program (and therefore jax)
# at collection time — the same cost every test module in this suite
# already pays by importing jax at module level; the payoff is that a
# newly registered family appears as a test id with zero test edits.
def _all_program_instances():
    from repro.core import program as program_mod

    return program_mod.test_instances()


@pytest.fixture(params=_all_program_instances(),
                ids=lambda p: p.family)
def lane_program(request):
    """Every registered LaneProgram family, one canonical instance each."""
    return request.param


def run_program_invariance_sweep(program, mesh_sizes=(1,), g=5,
                                 quantiles=(0.5, 0.9), t=400, seed=9,
                                 data_seed=4):
    """Assert `program` is bit-exact across backend × chunking × mesh.

    Builds one fleet per (backend, chunk_t, mesh) configuration, ingests the
    same [t, g] stream split across ingest()/ingest_stream() calls, and
    requires identical estimates AND identical full plane state everywhere.
    Returns the reference estimate plane for optional further checks.
    """
    import jax
    from repro.api import FleetSpec, QuantileFleet
    from repro.parallel.group_sharding import group_mesh

    items = np.random.default_rng(data_seed).integers(
        0, 800, (t, g)).astype(np.float32)
    n_dev = len(jax.devices())
    configs = [("jnp", 4096, None), ("fused", 64, None), ("fused", 333, None)]
    for n in mesh_sizes:
        if n <= n_dev:
            configs.append(("sharded", 100, group_mesh(n)))

    plane_fields = program.layout.plane_fields
    ref_est = ref_state = ref_cfg = None
    for backend, chunk, mesh in configs:
        spec = FleetSpec(num_groups=g, quantiles=quantiles, backend=backend,
                         chunk_t=chunk, mesh=mesh, program=program)
        fl = QuantileFleet.create(spec, seed=seed)
        cut = max(1, t // 3)
        fl = fl.ingest(items[:cut]).ingest_stream([items[cut:cut + 51],
                                                   items[cut + 51:]])
        est = fl.estimate()
        sk = fl._lane_sketch()
        state = {f: np.asarray(getattr(sk, f)) for f in plane_fields}
        if ref_est is None:
            ref_est, ref_state, ref_cfg = est, state, (backend, chunk)
            continue
        np.testing.assert_array_equal(
            ref_est, est,
            err_msg=f"{program.family}: estimates diverge between "
                    f"{ref_cfg} and ({backend}, {chunk})")
        for f in plane_fields:
            np.testing.assert_array_equal(
                ref_state[f], state[f],
                err_msg=f"{program.family}: plane {f!r} diverges between "
                        f"{ref_cfg} and ({backend}, {chunk})")
    return ref_est


@pytest.fixture
def program_sweep():
    """The shared harness as a fixture (callable) for test modules."""
    return run_program_invariance_sweep
