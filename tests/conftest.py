# NOTE: do NOT set XLA_FLAGS / host device count here. Smoke tests and
# benchmarks must see the single real CPU device; only launch/dryrun.py
# forces 512 placeholder devices (in its own process).
import os
import sys

# Make `src/` importable without installation (PYTHONPATH=src also works).
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "kernel: Pallas kernel validation tests")
    config.addinivalue_line("markers", "slow: long-running subprocess tests")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
