"""Checkpointing: atomic commit, keep-k GC, exact restore."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import checkpoint as ck


def _state(step):
    return {"w": jnp.arange(12.0).reshape(3, 4) * (step + 1),
            "b": jnp.ones((4,)) * step,
            "step": jnp.asarray(step)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    ck.save_checkpoint(d, 10, _state(10))
    restored, step = ck.restore_checkpoint(d, _state(0))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(_state(10)["w"]))


def test_only_committed_checkpoints_visible(tmp_path):
    d = str(tmp_path)
    ck.save_checkpoint(d, 5, _state(5))
    # simulate a crash mid-write: tmp dir exists, no marker
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    # and a dir without marker (crashed between rename and marker)
    os.makedirs(os.path.join(d, "step_00000008"))
    assert ck.committed_steps(d) == [5]
    _, step = ck.restore_checkpoint(d, _state(0))
    assert step == 5


def test_keep_k_gc(tmp_path):
    d = str(tmp_path)
    for s in (10, 20, 30, 40, 50):
        ck.save_checkpoint(d, s, _state(s), keep=2)
    assert ck.committed_steps(d) == [40, 50]
    restored, step = ck.restore_checkpoint(d, _state(0))
    assert step == 50


def test_restore_specific_step(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3):
        ck.save_checkpoint(d, s, _state(s), keep=5)
    restored, step = ck.restore_checkpoint(d, _state(0), step=2)
    assert step == 2
    assert float(restored["b"][0]) == 2.0


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore_checkpoint(str(tmp_path), _state(0))
