"""Checkpointing: atomic commit, keep-k GC, exact restore."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import checkpoint as ck


def _state(step):
    return {"w": jnp.arange(12.0).reshape(3, 4) * (step + 1),
            "b": jnp.ones((4,)) * step,
            "step": jnp.asarray(step)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    ck.save_checkpoint(d, 10, _state(10))
    restored, step = ck.restore_checkpoint(d, _state(0))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(_state(10)["w"]))


def test_only_committed_checkpoints_visible(tmp_path):
    d = str(tmp_path)
    ck.save_checkpoint(d, 5, _state(5))
    # simulate a crash mid-write: tmp dir exists, no marker
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    # and a dir without marker (crashed between rename and marker)
    os.makedirs(os.path.join(d, "step_00000008"))
    assert ck.committed_steps(d) == [5]
    _, step = ck.restore_checkpoint(d, _state(0))
    assert step == 5


def test_keep_k_gc(tmp_path):
    d = str(tmp_path)
    for s in (10, 20, 30, 40, 50):
        ck.save_checkpoint(d, s, _state(s), keep=2)
    assert ck.committed_steps(d) == [40, 50]
    restored, step = ck.restore_checkpoint(d, _state(0))
    assert step == 50


def test_restore_specific_step(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3):
        ck.save_checkpoint(d, s, _state(s), keep=5)
    restored, step = ck.restore_checkpoint(d, _state(0), step=2)
    assert step == 2
    assert float(restored["b"][0]) == 2.0


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore_checkpoint(str(tmp_path), _state(0))


def test_frugal2u_state_serializes_as_two_words_per_group(tmp_path):
    """Frugal-2U fleets hit disk as m + ONE packed int32 word per group —
    the paper's memory claim holds in the checkpoint bytes — and restore
    bit-exactly to the unpacked (m, step, sign) view."""
    from repro.core.frugal import Frugal2UState

    g = 64
    rng = np.random.default_rng(0)
    mon = Frugal2UState(
        m=jnp.asarray(rng.normal(100.0, 10.0, g), jnp.float32),
        step=jnp.asarray(rng.uniform(-30.0, 30.0, g), jnp.float32),
        sign=jnp.asarray(rng.choice([-1.0, 1.0], g), jnp.float32))
    state = {"w": jnp.ones((3,)), "monitor": mon}
    d = str(tmp_path)
    ck.save_checkpoint(d, 1, state)

    # on-disk: the sketch contributes exactly 2 leaves of G words each
    data = np.load(os.path.join(d, "step_00000001", "shard_0.npz"))
    leaves = [data[k] for k in sorted(data.files)]
    assert len(leaves) == 3  # w + (m, packed step_sign)
    sketch_leaves = [a for a in leaves if a.shape == (g,)]
    assert sorted(str(a.dtype) for a in sketch_leaves) == ["float32", "int32"]

    like = {"w": jnp.zeros((3,)),
            "monitor": Frugal2UState(m=jnp.zeros(g), step=jnp.zeros(g),
                                     sign=jnp.zeros(g))}
    restored, step = ck.restore_checkpoint(d, like)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["monitor"].m),
                                  np.asarray(mon.m))
    np.testing.assert_array_equal(np.asarray(restored["monitor"].step),
                                  np.asarray(mon.step))
    np.testing.assert_array_equal(np.asarray(restored["monitor"].sign),
                                  np.asarray(mon.sign))


def test_restore_accepts_abstract_like_with_sketches(tmp_path):
    """`like` may be an abstract (eval_shape / dry-run) template — restore
    must only read shapes/dtypes off it, never run math on its leaves."""
    from repro.core.frugal import Frugal2UState

    g = 8
    mon = Frugal2UState(m=jnp.arange(g, dtype=jnp.float32),
                        step=jnp.full((g,), 2.0), sign=jnp.ones((g,)))
    d = str(tmp_path)
    ck.save_checkpoint(d, 2, {"monitor": mon})
    abstract_like = {"monitor": Frugal2UState(
        m=jax.ShapeDtypeStruct((g,), jnp.float32),
        step=jax.ShapeDtypeStruct((g,), jnp.float32),
        sign=jax.ShapeDtypeStruct((g,), jnp.float32))}
    restored, step = ck.restore_checkpoint(d, abstract_like)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["monitor"].step),
                                  np.asarray(mon.step))
    np.testing.assert_array_equal(np.asarray(restored["monitor"].sign),
                                  np.asarray(mon.sign))


def test_restore_refuses_leaf_count_mismatch(tmp_path):
    """A checkpoint whose stored leaf count disagrees with the target
    structure (e.g. a pre-packing format-1 layout) must raise, not silently
    zip leaves into the wrong slots."""
    d = str(tmp_path)
    ck.save_checkpoint(d, 3, {"a": jnp.ones(2), "b": jnp.ones(3)})
    with pytest.raises(ValueError, match="leaves"):
        ck.restore_checkpoint(
            d, {"a": jnp.zeros(2), "b": jnp.zeros(3), "c": jnp.zeros(1)})


# ------------------------------------------- format-3 error paths (pinned)
def test_restore_truncated_manifest_raises_named_error(tmp_path):
    """A half-written manifest.json (protocol bypassed: manual copy, disk
    fault) must raise a ValueError naming the file, not a bare JSON parse
    error from somewhere inside restore."""
    d = str(tmp_path)
    path = ck.save_checkpoint(d, 5, {"w": jnp.ones(4)})
    mf = os.path.join(path, "manifest.json")
    with open(mf) as f:
        blob = f.read()
    with open(mf, "w") as f:
        f.write(blob[: len(blob) // 2])   # truncate mid-JSON
    with pytest.raises(ValueError, match="corrupt or truncated"):
        ck.restore_checkpoint(d, {"w": jnp.zeros(4)})


def test_restore_wrong_num_leaves_for_sketch_tree(tmp_path):
    """A sketch-bearing tree restored against a template with a different
    lane plane (extra leaves) must refuse via the manifest leaf count."""
    from repro.core import DriftConfig, GroupedQuantileSketch

    d = str(tmp_path)
    plain = GroupedQuantileSketch.create(6, quantile=0.5, algo="2u")
    ck.save_checkpoint(d, 1, {"sk": plain})       # 3 packed leaves
    windowed_like = {"sk": GroupedQuantileSketch.create(
        6, quantile=0.5, algo="2u",
        drift=DriftConfig(mode="window", window=8))}   # 5 packed leaves
    with pytest.raises(ValueError, match="leaves"):
        ck.restore_checkpoint(d, windowed_like)


def test_format2_checkpoint_under_format3_sketch_reader(tmp_path):
    """Format 2 predates whole-GroupedQuantileSketch packing: such a node's
    state went to disk as its raw dataclass leaves (m, step, sign, quantile
    = 4 leaves). Restoring one of those trees under a format-3 reader whose
    template holds the packed node (3 leaves) must refuse loudly instead of
    zipping leaves into the wrong slots."""
    import json as _json

    from repro.core import GroupedQuantileSketch

    d = str(tmp_path)
    g = 5
    # Write the checkpoint the way the format-2 writer laid this tree out:
    # raw leaves, no _PackedSketchNode. (save_checkpoint of plain arrays
    # uses the same layout; only the manifest format tag differs.)
    raw = {"sk_m": jnp.zeros(g), "sk_step": jnp.ones(g),
           "sk_sign": jnp.ones(g), "sk_quantile": jnp.full((g,), 0.5)}
    path = ck.save_checkpoint(d, 7, raw)
    mf = os.path.join(path, "manifest.json")
    with open(mf) as f:
        manifest = _json.load(f)
    manifest["format"] = 2
    with open(mf, "w") as f:
        _json.dump(manifest, f)

    like = {"sk": GroupedQuantileSketch.create(g, quantile=0.5, algo="2u")}
    with pytest.raises(ValueError, match="format 2"):
        ck.restore_checkpoint(d, like)


# ---------------------------------------- format-4 integrity + GC/scan races
def test_format4_manifest_carries_per_leaf_crc32(tmp_path):
    import json as _json
    import zlib as _zlib

    d = str(tmp_path)
    path = ck.save_checkpoint(d, 1, _state(1))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = _json.load(f)
    assert manifest["format"] == 4
    assert len(manifest["crc32"]) == manifest["num_leaves"]
    data = np.load(os.path.join(path, "shard_0.npz"))
    for i, crc in enumerate(manifest["crc32"]):
        arr = data[f"leaf_{i}"]
        assert _zlib.crc32(np.ascontiguousarray(arr).tobytes()) \
            & 0xFFFFFFFF == crc


def test_crc_mismatch_quarantines_and_falls_back(tmp_path):
    """Flip one data byte inside an otherwise perfectly valid npz: only the
    format-4 manifest CRC can catch it. Restore quarantines the step
    (marker gone, dir renamed *.corrupt) and falls back."""
    from repro.resilience import chaos

    d = str(tmp_path)
    ck.save_checkpoint(d, 1, _state(1))
    ck.save_checkpoint(d, 2, _state(2))
    chaos.corrupt_leaf_bytes(os.path.join(d, "step_00000002"), "rewrite")
    restored, step = ck.restore_checkpoint(d, _state(0))
    assert step == 1
    assert float(restored["b"][0]) == 1.0
    assert ck.committed_steps(d) == [1]
    assert os.path.isdir(os.path.join(d, "step_00000002.corrupt"))


def test_gc_never_removes_newest_even_with_keep_zero(tmp_path):
    """keep<=0 is clamped to 1: GC may never delete the only checkpoint a
    crash recovery could restore from."""
    d = str(tmp_path)
    for s in (1, 2, 3):
        ck.save_checkpoint(d, s, _state(s), keep=0)
    assert ck.committed_steps(d) == [3]
    _, step = ck.restore_checkpoint(d, _state(0))
    assert step == 3


def test_scan_tolerates_step_dir_vanishing_midway(tmp_path):
    """GC/restore race: a marker whose step directory is already gone (GC
    removed it between listing and read) is skipped silently and the scan
    falls back to an older intact step — no crash, no quarantine of the
    older step."""
    import shutil

    d = str(tmp_path)
    ck.save_checkpoint(d, 1, _state(1))
    ck.save_checkpoint(d, 2, _state(2))
    # simulate the race: dir gone, marker still listed
    shutil.rmtree(os.path.join(d, "step_00000002"))
    restored, step = ck.restore_checkpoint(d, _state(0))
    assert step == 1
    assert float(restored["b"][0]) == 1.0


def test_gc_removes_marker_before_directory(tmp_path):
    """The GC order contract behind the race tolerance above: after GC, no
    marker may point at a deleted directory (readers only consider marked
    steps, so marker-first removal keeps every visible step complete)."""
    d = str(tmp_path)
    for s in (1, 2, 3, 4):
        ck.save_checkpoint(d, s, _state(s), keep=2)
    for s in ck.committed_steps(d):
        assert os.path.isdir(os.path.join(d, f"step_{s:08d}"))
    assert ck.committed_steps(d) == [3, 4]


def test_idempotent_resave_skips_committed_step(tmp_path):
    d = str(tmp_path)
    path1 = ck.save_checkpoint(d, 1, _state(1))
    path2 = ck.save_checkpoint(d, 1, _state(99))   # already committed: no-op
    assert path1 == path2
    restored, _ = ck.restore_checkpoint(d, _state(0))
    assert float(restored["b"][0]) == 1.0
