"""Checkpointing: atomic commit, keep-k GC, exact restore."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import checkpoint as ck


def _state(step):
    return {"w": jnp.arange(12.0).reshape(3, 4) * (step + 1),
            "b": jnp.ones((4,)) * step,
            "step": jnp.asarray(step)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    ck.save_checkpoint(d, 10, _state(10))
    restored, step = ck.restore_checkpoint(d, _state(0))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(_state(10)["w"]))


def test_only_committed_checkpoints_visible(tmp_path):
    d = str(tmp_path)
    ck.save_checkpoint(d, 5, _state(5))
    # simulate a crash mid-write: tmp dir exists, no marker
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    # and a dir without marker (crashed between rename and marker)
    os.makedirs(os.path.join(d, "step_00000008"))
    assert ck.committed_steps(d) == [5]
    _, step = ck.restore_checkpoint(d, _state(0))
    assert step == 5


def test_keep_k_gc(tmp_path):
    d = str(tmp_path)
    for s in (10, 20, 30, 40, 50):
        ck.save_checkpoint(d, s, _state(s), keep=2)
    assert ck.committed_steps(d) == [40, 50]
    restored, step = ck.restore_checkpoint(d, _state(0))
    assert step == 50


def test_restore_specific_step(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3):
        ck.save_checkpoint(d, s, _state(s), keep=5)
    restored, step = ck.restore_checkpoint(d, _state(0), step=2)
    assert step == 2
    assert float(restored["b"][0]) == 2.0


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore_checkpoint(str(tmp_path), _state(0))


def test_frugal2u_state_serializes_as_two_words_per_group(tmp_path):
    """Frugal-2U fleets hit disk as m + ONE packed int32 word per group —
    the paper's memory claim holds in the checkpoint bytes — and restore
    bit-exactly to the unpacked (m, step, sign) view."""
    from repro.core.frugal import Frugal2UState

    g = 64
    rng = np.random.default_rng(0)
    mon = Frugal2UState(
        m=jnp.asarray(rng.normal(100.0, 10.0, g), jnp.float32),
        step=jnp.asarray(rng.uniform(-30.0, 30.0, g), jnp.float32),
        sign=jnp.asarray(rng.choice([-1.0, 1.0], g), jnp.float32))
    state = {"w": jnp.ones((3,)), "monitor": mon}
    d = str(tmp_path)
    ck.save_checkpoint(d, 1, state)

    # on-disk: the sketch contributes exactly 2 leaves of G words each
    data = np.load(os.path.join(d, "step_00000001", "shard_0.npz"))
    leaves = [data[k] for k in sorted(data.files)]
    assert len(leaves) == 3  # w + (m, packed step_sign)
    sketch_leaves = [a for a in leaves if a.shape == (g,)]
    assert sorted(str(a.dtype) for a in sketch_leaves) == ["float32", "int32"]

    like = {"w": jnp.zeros((3,)),
            "monitor": Frugal2UState(m=jnp.zeros(g), step=jnp.zeros(g),
                                     sign=jnp.zeros(g))}
    restored, step = ck.restore_checkpoint(d, like)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["monitor"].m),
                                  np.asarray(mon.m))
    np.testing.assert_array_equal(np.asarray(restored["monitor"].step),
                                  np.asarray(mon.step))
    np.testing.assert_array_equal(np.asarray(restored["monitor"].sign),
                                  np.asarray(mon.sign))


def test_restore_accepts_abstract_like_with_sketches(tmp_path):
    """`like` may be an abstract (eval_shape / dry-run) template — restore
    must only read shapes/dtypes off it, never run math on its leaves."""
    from repro.core.frugal import Frugal2UState

    g = 8
    mon = Frugal2UState(m=jnp.arange(g, dtype=jnp.float32),
                        step=jnp.full((g,), 2.0), sign=jnp.ones((g,)))
    d = str(tmp_path)
    ck.save_checkpoint(d, 2, {"monitor": mon})
    abstract_like = {"monitor": Frugal2UState(
        m=jax.ShapeDtypeStruct((g,), jnp.float32),
        step=jax.ShapeDtypeStruct((g,), jnp.float32),
        sign=jax.ShapeDtypeStruct((g,), jnp.float32))}
    restored, step = ck.restore_checkpoint(d, abstract_like)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["monitor"].step),
                                  np.asarray(mon.step))
    np.testing.assert_array_equal(np.asarray(restored["monitor"].sign),
                                  np.asarray(mon.sign))


def test_restore_refuses_leaf_count_mismatch(tmp_path):
    """A checkpoint whose stored leaf count disagrees with the target
    structure (e.g. a pre-packing format-1 layout) must raise, not silently
    zip leaves into the wrong slots."""
    d = str(tmp_path)
    ck.save_checkpoint(d, 3, {"a": jnp.ones(2), "b": jnp.ones(3)})
    with pytest.raises(ValueError, match="leaves"):
        ck.restore_checkpoint(
            d, {"a": jnp.zeros(2), "b": jnp.zeros(3), "c": jnp.zeros(1)})
