"""The ops.py padding contract, pinned for the program kernel pair:

  * T padding: NaN-padded ticks are bit-identical no-ops (NaN compares False
    both ways, so a padded tick never moves state);
  * G padding: lanes beyond the real lane count carry the layout's dummy
    state and are dropped on return — real lanes must be bit-identical to an
    unpadded call.

The kernel keys its on-chip RNG on absolute indices, so padding must not
perturb the uniforms real ticks consume — for ANY registered program.

Also pinned here: the interpret-dispatch seam. Explicit ``interpret=False``
off tpu/gpu must raise a ValueError naming ``frugal_update_auto`` (the old
seam forced the compiled Pallas path and crashed in the Mosaic lowering),
while ``interpret=None`` must pick a working lowering per platform.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import program as program_mod
from repro.kernels import (frugal_update_blocked, frugal_update_sparse,
                           frugal_update_auto)

SEED = 424242


def _mk(t, g, seed=0, domain=300):
    rng = np.random.default_rng(seed)
    items = jnp.asarray(rng.integers(0, domain, (t, g)), jnp.float32)
    m = jnp.asarray(rng.integers(0, domain, g), jnp.float32)
    return items, m


def _init_planes(program, m):
    layout = program.layout
    return tuple(
        m if f == "m" else (jnp.array(m) if f in layout.heads
                            else jnp.ones_like(m))
        for f in layout.plane_fields)


@pytest.fixture(params=[p.family for p in program_mod.test_instances()])
def program(request):
    return next(p for p in program_mod.test_instances()
                if p.family == request.param)


# ------------------------------------------------------------- NaN tick no-op
def test_nan_padded_ticks_are_bit_identical_noops(program):
    t, g = 96, 130
    items, m = _mk(t, g, seed=1)
    qv = jnp.full((g,), 0.5, jnp.float32)
    planes = _init_planes(program, m)
    nan_block = jnp.full((64, g), jnp.nan, jnp.float32)
    out1 = frugal_update_blocked(items, planes, qv, SEED, program=program,
                                 interpret=True)
    out2 = frugal_update_blocked(jnp.concatenate([items, nan_block]), planes,
                                 qv, SEED, program=program, interpret=True)
    for f, a, b in zip(program.layout.plane_fields, out1, out2):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{program.family}: {f} perturbed by NaN ticks")


# ------------------------------------------------------- G-lane padding drop
@pytest.mark.parametrize("g", [1, 127, 129, 250])
def test_padded_g_lanes_are_dropped(program, g):
    """A non-multiple-of-block G must return exactly [G] real lanes, each
    bit-identical to what a wider (pre-padded) call computes for them."""
    t = 64
    items, m = _mk(t, g, seed=g)
    qv = jnp.full((g,), 0.5, jnp.float32)
    planes = _init_planes(program, m)
    out = frugal_update_blocked(items, planes, qv, SEED, program=program,
                                interpret=True)
    assert all(x.shape == (g,) for x in out)

    # widen by hand with junk lanes; real lanes must be untouched
    gp = (-g) % 128
    items_w = jnp.pad(items, ((0, 0), (0, gp)), constant_values=123.0)
    q_w = jnp.pad(qv, (0, gp), constant_values=0.25)
    layout = program.layout
    planes_w = tuple(
        jnp.pad(p, (0, gp), constant_values=7.0 if f in layout.heads else 1.0)
        for f, p in zip(layout.plane_fields, planes))
    out_w = frugal_update_blocked(items_w, planes_w, q_w, SEED,
                                  program=program, interpret=True)
    for f, a, b in zip(layout.plane_fields, out, out_w):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)[:g],
            err_msg=f"{program.family}: {f} real lanes perturbed")


# ------------------------------------------------- interpret-dispatch seam
# These tests only make sense where no compiled lowering exists; the CI
# runners (CPU) are exactly that environment.
_cpu_only = pytest.mark.skipif(
    jnp.zeros(1).device.platform in ("tpu", "gpu"),
    reason="dispatch-refusal arms are for platforms without a compiled "
           "kernel lowering")


@_cpu_only
def test_explicit_compiled_request_off_accelerator_refuses(program):
    """interpret=False off tpu/gpu: a ValueError naming the auto entry
    point, for the dense AND the sparse seam — never a Mosaic crash."""
    t, g = 8, 4
    items, m = _mk(t, g)
    planes = _init_planes(program, m)
    qv = jnp.full((g,), 0.5, jnp.float32)
    with pytest.raises(ValueError, match="frugal_update_auto"):
        frugal_update_blocked(items, planes, qv, SEED, program=program,
                              interpret=False)
    ticks = jnp.zeros((g,), jnp.int32)
    with pytest.raises(ValueError, match="frugal_update_auto"):
        frugal_update_sparse(jnp.arange(g), jnp.ones(g),
                             jnp.ones(g, jnp.int32), planes, ticks, qv,
                             SEED, program=program, interpret=False)


@_cpu_only
def test_default_dispatch_runs_and_matches_interpret_kernel(program):
    """interpret=None picks a WORKING lowering per platform: the sparse
    seam routes to the jitted scatter pair on CPU (the old seam only
    spared None, so this pins the fallback arm), bit-identical to the
    interpret-mode scatter kernel; the dense auto facade runs the scan."""
    g = 5
    _, m = _mk(1, g)
    planes = _init_planes(program, m)
    ticks = jnp.zeros((g,), jnp.int32)
    qv = jnp.full((g,), 0.5, jnp.float32)
    lanes = jnp.arange(4, dtype=jnp.int32)
    vals = jnp.asarray([5.0, 50.0, 500.0, 5000.0], jnp.float32)
    mask = jnp.ones((4,), jnp.int32)
    pl_none, tk_none = frugal_update_sparse(
        lanes, vals, mask, planes, ticks, qv, SEED, program=program)
    pl_int, tk_int = frugal_update_sparse(
        lanes, vals, mask, planes, ticks, qv, SEED, program=program,
        interpret=True)
    for f, a, b in zip(program.layout.plane_fields, pl_none, pl_int):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{program.family}: {f} diverges between the None "
                    "dispatch and the interpret scatter kernel")
    np.testing.assert_array_equal(np.asarray(tk_none), np.asarray(tk_int))
    items, _ = _mk(16, g)
    out = frugal_update_auto(items, planes, qv, seed=SEED, program=program)
    assert all(x.shape == (g,) for x in out)
