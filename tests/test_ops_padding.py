"""The ops.py padding contract, pinned down for BOTH kernel generations:

  * T padding: NaN-padded ticks are bit-identical no-ops (NaN compares False
    both ways, so a padded tick never moves state);
  * G padding: lanes beyond the real group count carry dummy state and are
    dropped on return — real lanes must be bit-identical to an unpadded call.

The fused kernels additionally key their on-chip RNG on absolute indices, so
padding must not perturb the uniforms real ticks consume.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import (
    frugal1u_update_blocked_fused,
    frugal2u_update_blocked_fused,
)
# Warning-free internal impls of the deprecated rand-operand wrappers:
# tier-1 runs with DeprecationWarning promoted to error (pytest.ini), and
# only tests/test_deprecations.py may expect the shim's warning.
from repro.kernels.ops import (
    _frugal1u_update_blocked as frugal1u_update_blocked,
    _frugal2u_update_blocked as frugal2u_update_blocked,
)

SEED = 424242


def _mk(t, g, seed=0, domain=300):
    rng = np.random.default_rng(seed)
    items = jnp.asarray(rng.integers(0, domain, (t, g)), jnp.float32)
    rand = jnp.asarray(rng.random((t, g)), jnp.float32)
    m = jnp.asarray(rng.integers(0, domain, g), jnp.float32)
    return items, rand, m


# ------------------------------------------------------------- NaN tick no-op
@pytest.mark.parametrize("entry", ["old", "fused"])
def test_nan_padded_ticks_are_bit_identical_noops_1u(entry):
    t, g = 96, 130
    items, rand, m = _mk(t, g, seed=1)
    qv = jnp.full((g,), 0.5, jnp.float32)
    nan_block = jnp.full((64, g), jnp.nan, jnp.float32)
    items2 = jnp.concatenate([items, nan_block])
    if entry == "old":
        rand2 = jnp.concatenate([rand, jnp.full((64, g), 0.99, jnp.float32)])
        out1 = frugal1u_update_blocked(items, rand, m, qv, interpret=True)
        out2 = frugal1u_update_blocked(items2, rand2, m, qv, interpret=True)
    else:
        out1 = frugal1u_update_blocked_fused(items, m, qv, SEED, interpret=True)
        out2 = frugal1u_update_blocked_fused(items2, m, qv, SEED, interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


@pytest.mark.parametrize("entry", ["old", "fused"])
def test_nan_padded_ticks_are_bit_identical_noops_2u(entry):
    t, g = 96, 130
    items, rand, m = _mk(t, g, seed=2)
    step = jnp.ones((g,), jnp.float32)
    sign = jnp.ones((g,), jnp.float32)
    qv = jnp.full((g,), 0.9, jnp.float32)
    nan_block = jnp.full((32, g), jnp.nan, jnp.float32)
    items2 = jnp.concatenate([items, nan_block])
    if entry == "old":
        rand2 = jnp.concatenate([rand, jnp.full((32, g), 0.01, jnp.float32)])
        out1 = frugal2u_update_blocked(items, rand, m, step, sign, qv,
                                       interpret=True)
        out2 = frugal2u_update_blocked(items2, rand2, m, step, sign, qv,
                                       interpret=True)
    else:
        out1 = frugal2u_update_blocked_fused(items, m, step, sign, qv, SEED,
                                             interpret=True)
        out2 = frugal2u_update_blocked_fused(items2, m, step, sign, qv, SEED,
                                             interpret=True)
    for a, b, name in zip(out1, out2, ("m", "step", "sign")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{name} perturbed by NaN ticks")


# ------------------------------------------------------- G-lane padding drop
@pytest.mark.parametrize("entry", ["old", "fused"])
@pytest.mark.parametrize("g", [1, 127, 129, 250])
def test_padded_g_lanes_are_dropped_1u(entry, g):
    """A non-multiple-of-block G must return exactly [G] real lanes, each
    bit-identical to what a wider (pre-padded) call computes for them."""
    t = 64
    items, rand, m = _mk(t, g, seed=g)
    qv = jnp.full((g,), 0.5, jnp.float32)
    if entry == "old":
        out = frugal1u_update_blocked(items, rand, m, qv, interpret=True)
    else:
        out = frugal1u_update_blocked_fused(items, m, qv, SEED, interpret=True)
    assert out.shape == (g,)

    # widen by hand with junk lanes; real lanes must be untouched
    gp = (-g) % 128
    items_w = jnp.pad(items, ((0, 0), (0, gp)), constant_values=123.0)
    m_w = jnp.pad(m, (0, gp), constant_values=7.0)
    q_w = jnp.pad(qv, (0, gp), constant_values=0.25)
    if entry == "old":
        rand_w = jnp.pad(rand, ((0, 0), (0, gp)), constant_values=0.9)
        out_w = frugal1u_update_blocked(items_w, rand_w, m_w, q_w, interpret=True)
    else:
        out_w = frugal1u_update_blocked_fused(items_w, m_w, q_w, SEED,
                                              interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_w)[:g])


@pytest.mark.parametrize("entry", ["old", "fused"])
def test_padded_g_lanes_are_dropped_2u(entry):
    t, g = 64, 130
    items, rand, m = _mk(t, g, seed=11)
    step = jnp.ones((g,), jnp.float32)
    sign = jnp.ones((g,), jnp.float32)
    qv = jnp.full((g,), 0.5, jnp.float32)
    if entry == "old":
        out = frugal2u_update_blocked(items, rand, m, step, sign, qv,
                                      interpret=True)
    else:
        out = frugal2u_update_blocked_fused(items, m, step, sign, qv, SEED,
                                            interpret=True)
    assert all(x.shape == (g,) for x in out)

    gp = (-g) % 128
    items_w = jnp.pad(items, ((0, 0), (0, gp)), constant_values=50.0)
    m_w = jnp.pad(m, (0, gp), constant_values=0.0)
    step_w = jnp.pad(step, (0, gp), constant_values=1.0)
    sign_w = jnp.pad(sign, (0, gp), constant_values=1.0)
    q_w = jnp.pad(qv, (0, gp), constant_values=0.5)
    if entry == "old":
        rand_w = jnp.pad(rand, ((0, 0), (0, gp)), constant_values=0.5)
        out_w = frugal2u_update_blocked(items_w, rand_w, m_w, step_w, sign_w,
                                        q_w, interpret=True)
    else:
        out_w = frugal2u_update_blocked_fused(items_w, m_w, step_w, sign_w,
                                              q_w, SEED, interpret=True)
    for a, b, name in zip(out, out_w, ("m", "step", "sign")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[:g],
                                      err_msg=f"{name} real lanes perturbed")
