"""The ops.py padding contract, pinned for the program kernel pair:

  * T padding: NaN-padded ticks are bit-identical no-ops (NaN compares False
    both ways, so a padded tick never moves state);
  * G padding: lanes beyond the real lane count carry the layout's dummy
    state and are dropped on return — real lanes must be bit-identical to an
    unpadded call.

The kernel keys its on-chip RNG on absolute indices, so padding must not
perturb the uniforms real ticks consume — for ANY registered program.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import program as program_mod
from repro.kernels import frugal_update_blocked

SEED = 424242


def _mk(t, g, seed=0, domain=300):
    rng = np.random.default_rng(seed)
    items = jnp.asarray(rng.integers(0, domain, (t, g)), jnp.float32)
    m = jnp.asarray(rng.integers(0, domain, g), jnp.float32)
    return items, m


def _init_planes(program, m):
    layout = program.layout
    return tuple(
        m if f == "m" else (jnp.array(m) if f in layout.heads
                            else jnp.ones_like(m))
        for f in layout.plane_fields)


@pytest.fixture(params=[p.family for p in program_mod.test_instances()])
def program(request):
    return next(p for p in program_mod.test_instances()
                if p.family == request.param)


# ------------------------------------------------------------- NaN tick no-op
def test_nan_padded_ticks_are_bit_identical_noops(program):
    t, g = 96, 130
    items, m = _mk(t, g, seed=1)
    qv = jnp.full((g,), 0.5, jnp.float32)
    planes = _init_planes(program, m)
    nan_block = jnp.full((64, g), jnp.nan, jnp.float32)
    out1 = frugal_update_blocked(items, planes, qv, SEED, program=program,
                                 interpret=True)
    out2 = frugal_update_blocked(jnp.concatenate([items, nan_block]), planes,
                                 qv, SEED, program=program, interpret=True)
    for f, a, b in zip(program.layout.plane_fields, out1, out2):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{program.family}: {f} perturbed by NaN ticks")


# ------------------------------------------------------- G-lane padding drop
@pytest.mark.parametrize("g", [1, 127, 129, 250])
def test_padded_g_lanes_are_dropped(program, g):
    """A non-multiple-of-block G must return exactly [G] real lanes, each
    bit-identical to what a wider (pre-padded) call computes for them."""
    t = 64
    items, m = _mk(t, g, seed=g)
    qv = jnp.full((g,), 0.5, jnp.float32)
    planes = _init_planes(program, m)
    out = frugal_update_blocked(items, planes, qv, SEED, program=program,
                                interpret=True)
    assert all(x.shape == (g,) for x in out)

    # widen by hand with junk lanes; real lanes must be untouched
    gp = (-g) % 128
    items_w = jnp.pad(items, ((0, 0), (0, gp)), constant_values=123.0)
    q_w = jnp.pad(qv, (0, gp), constant_values=0.25)
    layout = program.layout
    planes_w = tuple(
        jnp.pad(p, (0, gp), constant_values=7.0 if f in layout.heads else 1.0)
        for f, p in zip(layout.plane_fields, planes))
    out_w = frugal_update_blocked(items_w, planes_w, q_w, SEED,
                                  program=program, interpret=True)
    for f, a, b in zip(layout.plane_fields, out, out_w):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)[:g],
            err_msg=f"{program.family}: {f} real lanes perturbed")
