"""End-to-end training on the synthetic corpus: loss decreases, frugal
monitors and quantile clipping engage, straggler detector fires."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models import build_model
from repro.optim import Optimizer, warmup_cosine
from repro.train import create_train_state, make_train_step
from repro.train.trainer import Trainer, StepTimeMonitor
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.monitor.registry import monitor_summary


@pytest.fixture(scope="module")
def trained():
    cfg = reduce_for_smoke(get_config("yi-6b"))
    model = build_model(cfg)
    opt = Optimizer(kind="adamw", lr_fn=warmup_cosine(2e-3, 10, 150))
    corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=48, batch_size=8))
    it = corpus.iterate()
    example = next(it)
    state = create_train_state(model, opt, jax.random.PRNGKey(0),
                               example_batch=example)
    step_fn = make_train_step(model, opt, clip_mode="quantile")
    trainer = Trainer(model, opt, step_fn, it, log_every=1000)
    state = trainer.run(state, 120)
    return state, trainer


def test_loss_decreases(trained):
    state, trainer = trained
    losses = [m["loss"] for m in trainer.metrics_history]
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first - 0.3, f"no learning: {first:.3f} -> {last:.3f}"
    assert np.isfinite(losses[-1])


def test_monitors_learned_activation_quantiles(trained):
    state, _ = trained
    summ = monitor_summary(state.monitors)
    # after 120 steps the absmax q99 sketches must have moved off 0 and be
    # positive (activations exist)
    q99 = np.asarray(summ["act_absmax_q99"])
    assert q99.shape[0] > 0
    assert np.all(q99 > 0.0), q99
    q50 = np.asarray(summ["act_rms_q50"])
    assert np.all(q50 > 0.0)
    assert np.all(q50 <= q99 * 50)  # sane ordering at sketch scale


def test_quantile_clip_state_engaged(trained):
    state, _ = trained
    # the grad-norm sketches must have adapted (m moved off init 1.0 for at
    # least some blocks) and warmup counted up
    assert int(state.qclip.warmup) == 120
    m = np.asarray(state.qclip.sketch.m)
    assert np.any(np.abs(m - 1.0) > 1e-3)


def test_step_counter_and_rng_advance(trained):
    state, _ = trained
    assert int(state.step) == 120


def test_straggler_detector_flags_outlier():
    mon = StepTimeMonitor(margin=1.5)
    rng = np.random.default_rng(0)
    flags = []
    for i in range(200):
        dt = 0.10 + rng.normal(0, 0.005)
        flags.append(mon.observe(max(dt, 1e-3)))
    assert not any(flags[50:]), "false straggler flags on steady stream"
    assert mon.observe(0.5)  # 5x slower step must flag
    # and q99 estimate should be near the true ~100ms scale
    assert 50 < mon.q99_ms < 200
