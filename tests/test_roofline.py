"""The revived roofline package: HW registry, kernel bandwidth model,
block autotuner, and the compiled-cost feed.

Four pinned behaviors:
  * the per-platform HwSpec registry refuses to predict on unknown
    hardware (no silent v5e numbers) and maps real device_kind strings;
  * the analytic bytes-moved model tracks each registered StateLayout's
    plane/packing widths exactly (a new family's roofline is priced off
    its layout, no model edits);
  * the autotuner is deterministic, cached per (family, layout, hw,
    shape), VMEM-feasible — and its blocks are bit-exact vs the default
    blocks through the full facade (tuned blocks are just another
    chunking), for every registered program, via the conftest sweep's
    fleet path under kernels.block_override;
  * hlo_parse.compiled_cost reads real numbers from a compiled program
    module.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import program as program_mod
from repro.kernels import block_override, frugal_update_auto
from repro.roofline.analysis import (
    HW_REGISTRY, RooflineUnknownHardware, detect_hw, hw_for,
    match_device_kind, roofline_terms)
from repro.roofline.autotune import (
    autotune_blocks, autotune_cache_info, clear_autotune_cache)
from repro.roofline.hlo_parse import compiled_cost
from repro.roofline.kernel_model import (
    kernel_bytes_per_item, kernel_bytes_total, predict_kernel,
    vmem_footprint_bytes)


# ------------------------------------------------------------- HW registry
def test_unknown_hardware_refuses_to_predict():
    unk = hw_for("unknown")
    assert not unk.known
    layout = program_mod.family_base("2u").layout
    with pytest.raises(RooflineUnknownHardware, match="refusing"):
        predict_kernel(1024, 256, 1, layout, block_g=128, block_t=256,
                       hw=unk)
    with pytest.raises(RooflineUnknownHardware):
        roofline_terms(1e12, 1e9, 0.0, hw=unk)


def test_unrecognized_device_kind_maps_to_unknown():
    assert match_device_kind("Radeon RX 7900").name == "unknown"
    assert match_device_kind("TPU v5 lite").name == "tpu-v5e"
    assert match_device_kind("NVIDIA H100 80GB HBM3").name == "gpu-h100"
    assert match_device_kind("NVIDIA A100-SXM4-80GB").name == "gpu-a100"
    assert match_device_kind("TPU v4").name == "tpu-v4"
    assert match_device_kind("cpu").name == "cpu"


def test_detect_hw_matches_local_device():
    hw = detect_hw()
    assert hw.name in HW_REGISTRY
    # the suite runs on CI CPU runners; never 'unknown' there
    if jax.devices()[0].platform == "cpu":
        assert hw.name == "cpu" and hw.nominal


def test_registry_lookup_unknown_key_is_hard_error():
    with pytest.raises(KeyError, match="tpu-v9"):
        hw_for("tpu-v9")


# ------------------------------------------------- analytic bytes per layout
@pytest.mark.parametrize("prog", program_mod.test_instances(),
                         ids=lambda p: p.family)
def test_bytes_model_matches_layout_widths(prog):
    """bytes/item = Q·(item + 2·num_words·t_blocks/T words): the model must
    track the layout's PACKED word count — a windowed 2U program (4 words)
    prices exactly twice the state traffic of vanilla 2U (2 words)."""
    layout = prog.layout
    t, bt, q = 4096, 256, 3
    per_item = kernel_bytes_per_item(layout, q, block_t=bt, t=t)
    t_blocks = t // bt
    expected = q * (4.0 + 2.0 * layout.num_words * 4.0 * t_blocks / t)
    assert per_item == pytest.approx(expected, rel=1e-12)

    # whole-update total: items + amortized state + final estimates
    g = 1 << 10
    total = kernel_bytes_total(g, t, q, layout, block_t=bt)
    assert total == pytest.approx(
        t * g * q * kernel_bytes_per_item(layout, 1, block_t=bt, t=t)
        + g * q * 4.0, rel=1e-12)

    # block_t = T is the floor: state crosses HBM exactly once
    floor = kernel_bytes_per_item(layout, 1, block_t=t, t=t)
    assert floor == pytest.approx(4.0 + 2.0 * layout.num_words * 4.0 / t)
    assert kernel_bytes_per_item(layout, 1, block_t=64, t=t) > floor


def test_word_counts_differ_across_layouts():
    w1 = program_mod.family_base("1u").layout.num_words
    w2 = program_mod.family_base("2u").layout.num_words
    w4 = program_mod.family_base("2u-window").layout.num_words
    assert (w1, w2, w4) == (1, 2, 4)
    t = 1024
    b1 = kernel_bytes_per_item(program_mod.family_base("1u").layout, 1,
                               block_t=256, t=t)
    b4 = kernel_bytes_per_item(program_mod.family_base("2u-window").layout,
                               1, block_t=256, t=t)
    assert b4 - 4.0 == pytest.approx(4 * (b1 - 4.0), rel=1e-12)


def test_prediction_is_bandwidth_bound_at_scale():
    """At G = 2^22 the paper's claim must come out of the model: the
    bandwidth term dominates the fixed overheads on every registered
    accelerator spec."""
    layout = program_mod.family_base("2u").layout
    for name, hw in HW_REGISTRY.items():
        if not hw.known or hw.nominal:
            continue
        bg, bt = autotune_blocks(program_mod.family_base("2u"),
                                 1 << 22, 4096, 1, hw=hw)
        pred = predict_kernel(1 << 22, 4096, 1, layout, block_g=bg,
                              block_t=bt, hw=hw)
        assert pred["bandwidth_s"] > pred["overhead_s"], name


# ------------------------------------------------------------- autotuner
def test_autotune_cache_hit_miss():
    clear_autotune_cache()
    prog = program_mod.make_program("2u")
    hw = hw_for("tpu-v5e")
    b1 = autotune_blocks(prog, 1 << 20, 4096, 1, hw=hw)
    info = autotune_cache_info()
    assert (info.misses, info.hits) == (1, 0)
    # same (family_base, layout, hw, shape) — a HIT, including for a
    # parameterized variant of the same family (shared compile key)
    assert autotune_blocks(prog, 1 << 20, 4096, 1, hw=hw) == b1
    variant = program_mod.make_program("2u")
    assert autotune_blocks(variant, 1 << 20, 4096, 1, hw=hw) == b1
    info = autotune_cache_info()
    assert (info.misses, info.hits) == (1, 2)
    # different shape or layout — a MISS
    autotune_blocks(prog, 1 << 21, 4096, 1, hw=hw)
    autotune_blocks(program_mod.make_program("2u-window", window=96),
                    1 << 20, 4096, 1, hw=hw)
    info = autotune_cache_info()
    assert info.misses == 3


def test_autotuned_blocks_are_vmem_feasible_and_deterministic():
    for prog in program_mod.test_instances():
        for name in ("tpu-v5e", "tpu-v5p", "gpu-h100", "cpu"):
            hw = hw_for(name)
            bg, bt = autotune_blocks(prog, 1 << 22, 4096, 1, hw=hw)
            assert (bg, bt) == autotune_blocks(prog, 1 << 22, 4096, 1,
                                               hw=hw)
            assert vmem_footprint_bytes(prog.layout, block_g=bg,
                                        block_t=bt) <= hw.vmem_bytes


def test_autotune_unknown_hw_returns_defaults():
    from repro.roofline.autotune import DEFAULT_BLOCK_G, DEFAULT_BLOCK_T

    prog = program_mod.make_program("1u")
    assert autotune_blocks(prog, 1 << 22, 4096, 1, hw=hw_for("unknown")) \
        == (DEFAULT_BLOCK_G, DEFAULT_BLOCK_T)


# ----------------------------------------- tuned blocks are pure chunking
def test_tuned_blocks_bit_exact_via_facade_sweep(lane_program,
                                                 program_sweep):
    """The conftest invariance sweep under block_override: every fleet
    config ingests through the interpret-mode DMA kernel at the blocks the
    autotuner picks for a v5e — estimates and full plane state must be
    bit-identical to the default-dispatch sweep's reference."""
    ref = program_sweep(lane_program, g=5, t=220)
    with block_override(autotune_hw="tpu-v5e", kernel="dma"):
        tuned = program_sweep(lane_program, g=5, t=220)
    np.testing.assert_array_equal(ref, tuned)


def test_tuned_vs_default_direct_all_kernels():
    """Direct kernel-level pin across all three lowerings at tuned AND
    default blocks, one odd-shaped stream (forces padding)."""
    rng = np.random.default_rng(3)
    items = jnp.asarray(rng.integers(0, 700, (311, 7)), jnp.float32)
    for prog in program_mod.test_instances():
        layout = prog.layout
        planes = tuple(jnp.full((7,), layout.pad_fill(f), jnp.float32)
                       for f in layout.plane_fields)
        ref = frugal_update_auto(items, planes, 0.7, seed=11, program=prog)
        for kernel in ("grid", "dma", "gpu"):
            with block_override(autotune_hw="tpu-v5e", kernel=kernel):
                out = frugal_update_auto(items, planes, 0.7, seed=11,
                                         program=prog)
            for f, a, b in zip(layout.plane_fields, ref, out):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"{prog.family}/{kernel}: {f} diverges at "
                            "tuned blocks")


# ------------------------------------------------------ compiled-cost feed
def test_compiled_cost_on_real_program_module():
    """hlo_parse.compiled_cost against an actually-compiled program
    executable: nonzero FLOPs and bytes, scaling up with a wider fleet."""
    from repro.core import frugal

    prog = program_mod.family_base("2u")

    def build(g):
        items = jnp.zeros((32, g), jnp.float32)
        planes = tuple(jnp.zeros((g,), jnp.float32)
                       for _ in prog.layout.plane_fields)
        qv = jnp.full((g,), 0.5, jnp.float32)

        def run(items, planes, qv):
            out, _ = frugal.program_process_seeded(
                prog, planes, items, jnp.int32(1), qv)
            return out

        return jax.jit(run).lower(items, planes, qv).compile()

    small = compiled_cost(build(64))
    big = compiled_cost(build(4096))
    assert small["flops"] > 0 and small["bytes_accessed"] > 0
    assert big["bytes_accessed"] > small["bytes_accessed"]
