"""QuantileFleet facade (repro.api): the spec is bit-exactness.

A Q=1 fleet must reproduce the legacy entry points' trajectories bit-for-bit
(ingest_stream / sketch.process / ShardedGroupFleet) for any chunking × mesh;
Q>1 lanes must be invariant to backend, chunking, and lane-shard layout;
cursors must checkpoint and resume bit-exactly. The multi-device cases run
wherever >= 2 devices exist (the multi-device CI job forces 8)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import (FleetSpec, FrugalEstimator, QuantileEstimator,
                       QuantileFleet, StreamCursor, TopologySpec)
from repro.core import GroupedQuantileSketch, ingest_array, ingest_stream
from repro.core import rng as crng
from repro.parallel import ShardedGroupFleet, group_mesh

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


def _items(t, g, seed=0, domain=800):
    rng = np.random.default_rng(seed)
    return rng.integers(0, domain, (t, g)).astype(np.float32)


def _seed(key):
    return int(np.asarray(crng.seed_from_key(key)))


# ------------------------------------------------ Q=1 legacy bit-exactness
@pytest.mark.parametrize("algo", ["1u", "2u"])
@pytest.mark.parametrize("backend", ["jnp", "fused"])
def test_q1_fleet_reproduces_legacy_sketch_bit_for_bit(algo, backend):
    t, g = 350, 23
    items = _items(t, g, seed=1)
    key = jax.random.PRNGKey(3)
    legacy = GroupedQuantileSketch.create(g, quantile=0.7, algo=algo) \
        .process(jnp.asarray(items), key)
    spec = FleetSpec(num_groups=g, quantiles=(0.7,), algo=algo,
                     backend=backend, chunk_t=64)
    fleet = QuantileFleet.create(spec, seed=_seed(key))
    fleet = fleet.ingest(items[:100]).ingest(items[100:])
    np.testing.assert_array_equal(fleet.estimate(0.7), np.asarray(legacy.m))


@pytest.mark.parametrize("chunk_t", [32, 100, 1024])
def test_q1_ingest_stream_matches_legacy_ingest_stream(chunk_t):
    t, g = 500, 17
    items = _items(t, g, seed=2)
    key = jax.random.PRNGKey(5)
    sk = GroupedQuantileSketch.create(g, quantile=0.9, algo="2u")
    legacy = ingest_stream(sk, [items[:123], items[123:]], key,
                           chunk_t=chunk_t)
    spec = FleetSpec(num_groups=g, quantiles=(0.9,), chunk_t=chunk_t)
    fleet = QuantileFleet.create(spec, seed=_seed(key))
    fleet = fleet.ingest_stream([items[:123], items[123:]])
    np.testing.assert_array_equal(fleet.estimate(0.9), np.asarray(legacy.m))
    sk_f = fleet._lane_sketch()
    np.testing.assert_array_equal(np.asarray(sk_f.step),
                                  np.asarray(legacy.step))
    np.testing.assert_array_equal(np.asarray(sk_f.sign),
                                  np.asarray(legacy.sign))


def test_q1_sharded_fleet_reproduces_sharded_legacy():
    """A lane-sharded topology reproduces the low-level ShardedGroupFleet
    trajectory bit-for-bit (on one device the topology normalizes to the
    single placement — same bits, the cross-backend contract)."""
    t, g = 200, 13
    items = _items(t, g, seed=3)
    key = jax.random.PRNGKey(1)
    mesh = group_mesh(min(2, len(jax.devices())))
    legacy = ShardedGroupFleet.create(g, quantile=0.5, algo="2u", mesh=mesh)
    legacy = legacy.ingest_array(items, key, chunk_t=48)
    spec = FleetSpec(num_groups=g, quantiles=(0.5,), chunk_t=48,
                     topology=TopologySpec(lanes=min(2, len(jax.devices()))))
    fleet = QuantileFleet.create(spec, seed=_seed(key)).ingest(items)
    np.testing.assert_array_equal(fleet.estimate(0.5), legacy.estimate())


# ------------------------------------------------- Q>1 lane-plane invariance
def test_registered_programs_bit_exact_across_backend_chunking_mesh(
        lane_program, program_sweep):
    """THE shared sweep (tests/conftest.py): every registered LaneProgram
    — vanilla, drift, and DP rules alike — must produce bit-identical
    estimates AND full plane state across backend jnp/fused x two chunk
    sizes x split ingest/stream ingest x every available mesh size, on a
    Q=2 multi-quantile lane plane. New programs registered in
    core.program.test_instances() are swept automatically."""
    program_sweep(lane_program, mesh_sizes=(1, 2, 4, 8))


def test_multi_q_lane_hashes_its_own_stream():
    """Two lanes of one group with the SAME target still get distinct
    uniform streams (absolute lane-id keying) — their trajectories differ."""
    t = 400
    items = _items(t, 1, seed=5)
    spec = FleetSpec(num_groups=1, quantiles=(0.5, 0.5), backend="jnp")
    fl = QuantileFleet.create(spec, seed=0).ingest(items)
    a, b = fl.estimate()[0]
    # same item stream, same target, different uniforms -> (almost surely)
    # different walks; bit-equality would mean the lanes shared a stream
    sk = fl._lane_sketch()
    assert not np.array_equal(np.asarray(sk.step[0:1]),
                              np.asarray(sk.step[1:2])) or a != b


def test_g_offset_cursor_respected_on_every_backend():
    """Regression: the sharded branch used to DROP cursor.g_offset, so a
    column-slice fleet silently hashed the wrong lane streams on backend
    'sharded' only. All three backends must agree for non-zero g_offset."""
    t, g, off = 90, 5, 8
    items = _items(t, g, seed=12)
    qs = (0.5, 0.9)
    outs = []
    for backend, topo in (("jnp", None), ("fused", None),
                          ("fused", TopologySpec(
                              lanes=min(2, len(jax.devices()))))):
        spec = FleetSpec(num_groups=g, quantiles=qs, backend=backend,
                         chunk_t=32, topology=topo)
        fl = QuantileFleet.create(
            spec, cursor=StreamCursor.create(seed=3, g_offset=off))
        outs.append(fl.ingest(items).estimate())
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])
    # and g_offset actually matters: a zero-offset run differs
    fl0 = QuantileFleet.create(
        FleetSpec(num_groups=g, quantiles=qs, backend="jnp"), seed=3)
    assert not np.array_equal(fl0.ingest(items).estimate(), outs[0])
    # the offset fleet IS the column slice of a wider fleet (lane semantics)
    wide = QuantileFleet.create(
        FleetSpec(num_groups=g + off // len(qs), quantiles=qs,
                  backend="jnp"), seed=3)
    wide_items = np.concatenate(
        [_items(t, off // len(qs), seed=99), items], axis=1)
    lanes = wide.ingest(wide_items).estimate()[off // len(qs):]
    np.testing.assert_array_equal(lanes, outs[0])


if HAS_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(
        g=st.integers(min_value=1, max_value=9),
        nq=st.integers(min_value=1, max_value=4),
        chunk_t=st.integers(min_value=1, max_value=80),
        split=st.integers(min_value=0, max_value=120),
    )
    def test_property_backend_and_chunking_invariance(g, nq, chunk_t, split):
        t = 120
        items = _items(t, g, seed=g * 7 + nq)
        qs = tuple(float(q) for q in np.linspace(0.2, 0.9, nq))
        ref = QuantileFleet.create(
            FleetSpec(num_groups=g, quantiles=qs, backend="jnp"),
            seed=13).ingest(items)
        fused = QuantileFleet.create(
            FleetSpec(num_groups=g, quantiles=qs, backend="fused",
                      chunk_t=chunk_t), seed=13)
        fused = fused.ingest(items[:split]).ingest_stream([items[split:]])
        np.testing.assert_array_equal(ref.estimate(), fused.estimate())
else:  # pragma: no cover - exercised only without the dev deps
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_backend_and_chunking_invariance():
        pass


# ------------------------------------------------------- cursor semantics
def test_cursor_advances_functionally_and_wraps_i32():
    fl = QuantileFleet.create(FleetSpec(num_groups=2), seed=0)
    assert int(fl.cursor.t_offset) == 0
    f2 = fl.ingest(_items(5, 2))
    assert int(fl.cursor.t_offset) == 0      # original untouched
    assert int(f2.cursor.t_offset) == 5
    near_wrap = StreamCursor.create(seed=0, t_offset=2**31 - 2)
    wrapped = near_wrap.advance(5)
    assert int(wrapped.t_offset) == crng.wrap_i32(2**31 + 3)


def test_checkpoint_restores_cursor_and_trajectory_bit_exactly(tmp_path):
    t, g = 260, 7
    items = _items(t, g, seed=10)
    spec = FleetSpec(num_groups=g, quantiles=(0.5, 0.9), chunk_t=50)
    full = QuantileFleet.create(spec, seed=21).ingest(items)
    half = QuantileFleet.create(spec, seed=21).ingest(items[:130])
    half.checkpoint(str(tmp_path), step=3)
    resumed = QuantileFleet.restore(str(tmp_path), spec)
    assert int(resumed.cursor.t_offset) == 130
    assert int(resumed.cursor.seed) == 21
    done = resumed.ingest(items[130:])
    np.testing.assert_array_equal(done.estimate(), full.estimate())
    sk_a, sk_b = done._lane_sketch(), full._lane_sketch()
    np.testing.assert_array_equal(np.asarray(sk_a.step),
                                  np.asarray(sk_b.step))


def test_checkpoint_restore_across_backends(tmp_path):
    """format-3 checkpoints are backend-portable: save fused, restore
    sharded (and back), trajectories identical."""
    t, g = 140, 6
    items = _items(t, g, seed=11)
    qs = (0.5, 0.95)
    fused_spec = FleetSpec(num_groups=g, quantiles=qs, chunk_t=32)
    half = QuantileFleet.create(fused_spec, seed=4).ingest(items[:70])
    half.checkpoint(str(tmp_path), step=1)
    sharded_spec = FleetSpec(num_groups=g, quantiles=qs, chunk_t=32,
                             topology=TopologySpec(
                                 lanes=len(jax.devices())))
    resumed = QuantileFleet.restore(str(tmp_path), sharded_spec)
    done_sh = resumed.ingest(items[70:])
    done_ref = QuantileFleet.create(fused_spec, seed=4).ingest(items)
    np.testing.assert_array_equal(done_sh.estimate(), done_ref.estimate())


def test_ingest_refuses_event_clock_and_vice_versa():
    ev = QuantileFleet.create(FleetSpec(num_groups=2, backend="jnp"),
                              per_lane_clock=True)
    with pytest.raises(ValueError, match="per-lane cursor"):
        ev.ingest(_items(3, 2))
    block = QuantileFleet.create(FleetSpec(num_groups=2, backend="jnp"))
    with pytest.raises(ValueError, match="per-lane cursor"):
        block.tick_lanes_sparse(jnp.asarray([0]), jnp.asarray([1.0]))


# --------------------------------------------------------- event-lane mode
def test_tick_lanes_dense_equals_sparse_trajectory():
    spec = FleetSpec(num_groups=4, quantiles=(0.5, 0.9), backend="jnp")
    dense = QuantileFleet.create(spec, seed=5, per_lane_clock=True)
    sparse = QuantileFleet.create(spec, seed=5, per_lane_clock=True)
    rng = np.random.default_rng(0)
    lanes_hit = [0, 3, 5, 7, 3, 0, 6, 1]
    for lane in lanes_hit:
        v = float(rng.lognormal(2.0, 0.5))
        items = np.full((8,), np.nan, np.float32)
        items[lane] = v
        dense = dense.tick_lanes(items)
        sparse = sparse.tick_lanes_sparse(np.asarray([lane], np.int32),
                                          np.asarray([v], np.float32))
    np.testing.assert_array_equal(dense.estimate(), sparse.estimate())
    np.testing.assert_array_equal(np.asarray(dense.cursor.t_offset),
                                  np.asarray(sparse.cursor.t_offset))


def test_tick_lanes_sparse_mask_zero_is_true_noop():
    """mask=0 with a NON-NaN item must neither move the lane's state nor
    advance its clock — the whole round must equal one that never named the
    lane at all (the old behavior mutated state without the clock, silently
    desyncing the lane's counter-RNG stream)."""
    spec = FleetSpec(num_groups=6, quantiles=(0.5,), backend="jnp")
    padded = QuantileFleet.create(spec, seed=3, per_lane_clock=True)
    plain = QuantileFleet.create(spec, seed=3, per_lane_clock=True)
    warm_l = np.asarray([0, 2, 4], np.int32)
    warm_v = np.asarray([5.0, 7.0, 2.0], np.float32)
    padded = padded.tick_lanes_sparse(warm_l, warm_v)
    plain = plain.tick_lanes_sparse(warm_l, warm_v)
    # lane 0 rides along masked-out with a live (non-NaN) item
    padded = padded.tick_lanes_sparse(np.asarray([0, 2], np.int32),
                                      np.asarray([123.0, 9.0], np.float32),
                                      np.asarray([0, 1], np.int32))
    plain = plain.tick_lanes_sparse(np.asarray([2], np.int32),
                                    np.asarray([9.0], np.float32))
    np.testing.assert_array_equal(padded.estimate(), plain.estimate())
    np.testing.assert_array_equal(np.asarray(padded.cursor.t_offset),
                                  np.asarray(plain.cursor.t_offset))
    fields = spec.program.layout.plane_fields
    for f in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(padded._lane_sketch(), f)),
            np.asarray(getattr(plain._lane_sketch(), f)),
            err_msg=f"masked-out slot moved plane {f!r}")


def test_tick_lanes_mask_on_scalar_clock_raises():
    """A mask on a scalar-clock fleet used to be silently dropped; it now
    raises (every lane's tick advances together there — individual clocks
    cannot be held back)."""
    spec = FleetSpec(num_groups=4, quantiles=(0.5,), backend="jnp")
    fl = QuantileFleet.create(spec, seed=0)   # scalar clock
    with pytest.raises(ValueError, match="per-lane cursor"):
        fl.tick_lanes(np.ones(4, np.float32), np.ones(4, np.int32))
    # per-lane cursor accepts the same call
    fl2 = QuantileFleet.create(spec, seed=0, per_lane_clock=True)
    fl2.tick_lanes(np.ones(4, np.float32), np.ones(4, np.int32))


def test_tick_lanes_sparse_duplicate_check():
    spec = FleetSpec(num_groups=8, quantiles=(0.5,), backend="jnp")
    fl = QuantileFleet.create(spec, seed=1, per_lane_clock=True)
    with pytest.raises(ValueError, match="repeat within"):
        fl.tick_lanes_sparse(np.asarray([2, 2], np.int32),
                             np.asarray([1.0, 2.0], np.float32),
                             check_duplicates=True)
    with pytest.raises(ValueError, match="pad slots reuse"):
        fl.tick_lanes_sparse(np.asarray([1, 1], np.int32),
                             np.asarray([1.0, np.nan], np.float32),
                             np.asarray([1, 0], np.int32),
                             check_duplicates=True)
    # distinct lanes + clean pads pass the check
    fl.tick_lanes_sparse(np.asarray([1, 3, 5], np.int32),
                         np.asarray([1.0, 2.0, np.nan], np.float32),
                         np.asarray([1, 1, 0], np.int32),
                         check_duplicates=True)


def test_tick_lanes_sparse_donate_matches_functional():
    """donate=True (the serve path's in-place mode) must be bit-exact with
    the default functional round — only the buffer lifetime differs."""
    spec = FleetSpec(num_groups=5, quantiles=(0.5, 0.9), backend="jnp")
    fn = QuantileFleet.create(spec, seed=7, per_lane_clock=True)
    dn = QuantileFleet.create(spec, seed=7, per_lane_clock=True)
    rng = np.random.default_rng(2)
    for _ in range(6):
        k = int(rng.integers(1, 8))
        lanes = rng.choice(10, size=k, replace=False).astype(np.int32)
        vals = rng.integers(0, 500, k).astype(np.float32)
        fn = fn.tick_lanes_sparse(lanes, vals)
        dn = dn.tick_lanes_sparse(lanes, vals, donate=True)
    np.testing.assert_array_equal(fn.estimate(), dn.estimate())
    np.testing.assert_array_equal(np.asarray(fn.cursor.t_offset),
                                  np.asarray(dn.cursor.t_offset))


def test_tick_lanes_scalar_clock_inside_jit():
    """jnp-backend fleets ride inside jitted steps (the monitor path)."""
    spec = FleetSpec(num_groups=6, quantiles=(0.99,), backend="jnp")
    fl = QuantileFleet.create(spec, seed=2)

    @jax.jit
    def step(fleet, values):
        return fleet.tick_lanes(values)

    vals = np.abs(np.random.default_rng(1).normal(size=(20, 6))) \
        .astype(np.float32)
    ref = fl
    for v in vals:
        fl = step(fl, jnp.asarray(v))
        ref = ref.tick_lanes(jnp.asarray(v))
    np.testing.assert_array_equal(fl.estimate(), ref.estimate())
    assert int(fl.cursor.t_offset) == 20


def test_grow_groups_never_perturbs_existing_lanes():
    spec = FleetSpec(num_groups=3, quantiles=(0.5, 0.9), backend="jnp")
    small = QuantileFleet.create(spec, seed=8, per_lane_clock=True)
    big = QuantileFleet.create(
        FleetSpec(num_groups=16, quantiles=(0.5, 0.9), backend="jnp"),
        seed=8, per_lane_clock=True)
    rng = np.random.default_rng(3)
    for _ in range(50):
        lane = int(rng.integers(6))
        v = float(rng.lognormal(2.0, 0.4))
        small = small.tick_lanes_sparse(np.asarray([lane], np.int32),
                                        np.asarray([v], np.float32))
        big = big.tick_lanes_sparse(np.asarray([lane], np.int32),
                                    np.asarray([v], np.float32))
    grown = small.grow_groups(16)
    assert grown.num_lanes == 32
    np.testing.assert_array_equal(grown.estimate()[:3], small.estimate())
    for _ in range(50):
        lane = int(rng.integers(30))
        v = float(rng.lognormal(2.0, 0.4))
        grown = grown.tick_lanes_sparse(np.asarray([lane], np.int32),
                                        np.asarray([v], np.float32))
        big = big.tick_lanes_sparse(np.asarray([lane], np.int32),
                                    np.asarray([v], np.float32))
    np.testing.assert_array_equal(grown.estimate(), big.estimate())


# ------------------------------------------------------------- spec + misc
def test_fleet_spec_validation():
    with pytest.raises(ValueError, match="quantiles"):
        FleetSpec(num_groups=1, quantiles=(1.5,))
    with pytest.raises(ValueError, match="backend"):
        FleetSpec(num_groups=1, backend="gpu")
    with pytest.raises(ValueError, match="algo"):
        FleetSpec(num_groups=1, algo="3u")
    with pytest.raises(ValueError, match="chunk_t"):
        FleetSpec(num_groups=1, chunk_t=0)
    with pytest.raises(ValueError, match="num_groups"):
        FleetSpec(num_groups=0)
    # (mesh=-without-sharded rejection is pinned in test_deprecations.py —
    # the deprecated spelling lives only there and in the shim.)
    with pytest.raises(ValueError, match="TopologySpec"):
        FleetSpec(num_groups=1, topology="2x4")
    with pytest.raises(ValueError, match="scan engine"):
        FleetSpec(num_groups=1, backend="jnp",
                  topology=TopologySpec(data=2))
    spec = FleetSpec(num_groups=4, quantiles=(0.5, 0.9))
    assert spec.num_lanes == 8
    assert spec.lane(2, 0.9) == 5
    assert spec.memory_words() == 2
    assert FleetSpec(num_groups=1, algo="1u").memory_words() == 1


def test_estimate_shape_and_column_selection():
    fl = QuantileFleet.create(
        FleetSpec(num_groups=5, quantiles=(0.25, 0.75)), seed=0)
    fl = fl.ingest(_items(50, 5))
    plane = fl.estimate()
    assert plane.shape == (5, 2)
    np.testing.assert_array_equal(fl.estimate(quantile=0.75), plane[:, 1])
    with pytest.raises(ValueError):
        fl.estimate(quantile=0.5)


def test_frugal_estimator_conforms_and_replays():
    from repro.core.baselines import ExactQuantile, GKSummary

    est = FrugalEstimator(quantiles=(0.5, 0.9), seed=3)
    assert isinstance(est, QuantileEstimator)
    assert isinstance(GKSummary(), QuantileEstimator)
    assert isinstance(ExactQuantile(), QuantileEstimator)
    stream = np.random.default_rng(0).lognormal(3.0, 1.0, 5000)
    est.extend(stream)
    # two estimators with the same seed/targets replay bit-exactly,
    # regardless of insert/extend batching
    twin = FrugalEstimator(quantiles=(0.5, 0.9), seed=3)
    for v in stream[:100]:
        twin.insert(v)
    twin.extend(stream[100:])
    assert est.query(0.5) == twin.query(0.5)
    assert est.query(0.9) == twin.query(0.9)
    assert est.memory_words() == 4   # 2 words x 2 lanes
    with pytest.raises(ValueError):
        est.query(0.99)
