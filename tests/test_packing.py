"""core.packing edge domains: the paper's "two words + one bit" claim must
hold (or degrade safely) over the FULL float32 state space, not just the
values a healthy run produces.

Domains pinned here (see packing.py's encoding doc):
  * in-domain |step| in {0} ∪ [2^-63, 2^32): bit-exact round-trip, both
    directions, both step signs;
  * |step| >= 2^32 (incl ±inf): saturates to the largest in-domain float,
    step sign AND direction preserved;
  * |step| < 2^-63 (subnormals, ±0): flushes to zero, direction preserved;
  * NaN step: flushes to zero (a NaN's exponent would alias into the
    negative-direction range and corrupt the decoded sign);
  * NaN / ±inf ESTIMATES: `m` rides raw float32 next to the packed word —
    PackedSketchState round-trips them bit-for-bit.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.packing import (
    _MAX_STEP,
    pack_step_sign,
    step_sign_word_canonical,
    unpack_step_sign,
)
from repro.core.program import make_program
from repro.core.sketch import GroupedQuantileSketch
from repro.resilience.health import validate_planes

# Only the property tests need hypothesis; a missing dev dep must not kill
# collection under -x.
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


def _roundtrip(step, sign):
    s2, g2 = unpack_step_sign(pack_step_sign(jnp.float32(step),
                                             jnp.float32(sign)))
    return float(s2), float(g2)


def _expected(step: float, sign: float):
    """Reference semantics of the packed domain (mirrors the docstring)."""
    direction = -1.0 if sign < 0 else 1.0
    if np.isnan(step):
        return 0.0, direction
    clipped = float(np.clip(np.float32(step), -_MAX_STEP, _MAX_STEP))
    if abs(clipped) < 2.0 ** -63:
        return 0.0, direction
    return clipped, direction


@pytest.mark.parametrize("sign", [1.0, -1.0])
@pytest.mark.parametrize("step", [
    0.0, -0.0, 1.0, -1.0, 2.0 ** -63, -(2.0 ** -63), 0.75, 1e6,
    float(_MAX_STEP), -float(_MAX_STEP), 3.5, 1234567.0,
])
def test_in_domain_bit_exact(step, sign):
    s2, g2 = _roundtrip(step, sign)
    exp_s, exp_g = _expected(step, sign)
    assert s2 == exp_s and g2 == exp_g, (step, sign, s2, g2)


@pytest.mark.parametrize("sign", [1.0, -1.0])
@pytest.mark.parametrize("step", [
    2.0 ** 32, -(2.0 ** 32), 1e38, float("inf"), float("-inf"),
])
def test_saturation_keeps_direction(step, sign):
    s2, g2 = _roundtrip(step, sign)
    assert abs(s2) == float(_MAX_STEP)
    assert np.sign(s2) == np.sign(step)
    assert g2 == sign


@pytest.mark.parametrize("sign", [1.0, -1.0])
@pytest.mark.parametrize("step", [2.0 ** -64, -(2.0 ** -64), 1e-40, 5e-324])
def test_flush_to_zero_keeps_direction(step, sign):
    s2, g2 = _roundtrip(step, sign)
    assert s2 == 0.0
    assert g2 == sign


@pytest.mark.parametrize("sign", [1.0, -1.0])
def test_nan_step_flushes_safely(sign):
    s2, g2 = _roundtrip(float("nan"), sign)
    assert s2 == 0.0
    assert g2 == sign


def test_nan_inf_estimates_roundtrip_bitwise():
    """m is raw f32 next to the packed word: non-finite estimates survive
    packed()/from_packed() bit-for-bit (frugal m CAN leave the finite range
    only via non-finite stream items, but serialization must not care)."""
    m = jnp.asarray([np.nan, np.inf, -np.inf, -0.0, 1.5], jnp.float32)
    sk = GroupedQuantileSketch(
        m=m, step=jnp.ones_like(m), sign=-jnp.ones_like(m),
        quantile=jnp.float32(0.5), algo="2u")
    back = GroupedQuantileSketch.from_packed(sk.packed())
    np.testing.assert_array_equal(
        np.asarray(m).view(np.int32), np.asarray(back.m).view(np.int32))
    np.testing.assert_array_equal(np.asarray(back.sign), np.asarray(sk.sign))


if HAS_HYPOTHESIS:

    @settings(max_examples=300, deadline=None)
    @given(bits=st.integers(-2 ** 31, 2 ** 31 - 1),
           sign=st.sampled_from([1.0, -1.0]))
    def test_property_full_int32_bit_space(bits, sign):
        """Round-trip over EVERY float32 bit pattern as a step (covers the
        full int32 range incl. subnormals, both zeros, inf, NaN payloads)."""
        step = float(np.int32(bits).view(np.float32))
        s2, g2 = _roundtrip(step, sign)
        exp_s, exp_g = _expected(step, sign)
        assert (s2, g2) == (exp_s, exp_g), (hex(bits & 0xFFFFFFFF), step, sign)

    @settings(max_examples=200, deadline=None)
    @given(step=st.floats(width=32, allow_nan=True, allow_infinity=True),
           sign=st.sampled_from([1.0, -1.0]))
    def test_property_float_space_saturate_or_exact(step, sign):
        s2, g2 = _roundtrip(step, sign)
        exp_s, exp_g = _expected(step, sign)
        assert (s2, g2) == (exp_s, exp_g)

    @settings(max_examples=100, deadline=None)
    @given(exp=st.integers(-63, 31), mant=st.integers(0, 2 ** 23 - 1),
           neg=st.booleans(), sign=st.sampled_from([1.0, -1.0]))
    def test_property_in_domain_exponent_sweep_bit_exact(exp, mant, neg, sign):
        """Dense coverage of the exact-round-trip domain [2^-63, 2^32) via
        (exponent, mantissa) construction — every value must survive
        bit-for-bit including step's own sign."""
        step = np.float32((1.0 + mant * 2.0 ** -23) * 2.0 ** exp)
        if neg:
            step = -step
        s2, g2 = _roundtrip(float(step), sign)
        assert np.float32(s2).view(np.int32) == step.view(np.int32)
        assert g2 == sign

    @settings(max_examples=200, deadline=None)
    @given(exp=st.integers(-63, 31), mant=st.integers(0, 2 ** 23 - 1),
           neg=st.booleans(), sign=st.sampled_from([1.0, -1.0]),
           bit=st.integers(0, 31))
    def test_property_single_bit_flip_detectable_or_absorbed(
            exp, mant, neg, sign, bit):
        """The resilience layer's detectable-vs-absorbable map for a single
        bit flip of a packed (step, sign) word, pinned exactly:

        canonical words (what pack_step_sign can emit) are
          {w : w & 0x7FFFFFFF == 0} ∪ {e' ∈ [64, 158]} ∪ {e' ∈ [160, 254]}
        with e' = (w >> 23) & 0xFF. A flipped word either stays canonical
        (the flip is ABSORBED into a valid neighboring lane state — decodes
        finite, in-domain, sign exactly ±1) or is non-canonical, in which
        case decode canonicalizes it (re-packing the decoded value yields a
        DIFFERENT word — word-level detectability), and
        resilience.health's 'step' invariant flags it — except the one
        absorbed class e' == 0 with a non-zero mantissa, which decodes to
        the legitimate flushed state (0, ±1) and is deliberately silent."""
        step = np.float32((1.0 + mant * 2.0 ** -23) * 2.0 ** exp)
        if neg:
            step = -step
        word = int(np.asarray(pack_step_sign(jnp.float32(step),
                                             jnp.float32(sign))))
        u = (word & 0xFFFFFFFF) ^ (1 << bit)
        flipped = jnp.asarray(np.uint32(u).view(np.int32))

        e = (u >> 23) & 0xFF
        expect_canonical = ((u & 0x7FFFFFFF) == 0) or (64 <= e <= 158) \
            or (160 <= e <= 254)
        canonical = bool(np.asarray(step_sign_word_canonical(flipped)))
        assert canonical == expect_canonical, hex(u)

        s2, g2 = unpack_step_sign(flipped)
        s2, g2 = float(s2), float(g2)
        # Decode NEVER emits a state outside the lane domain — garbage in,
        # canonical out (no NaN/inf step, sign exactly ±1).
        assert g2 in (1.0, -1.0), hex(u)
        absorbed_zero = (e == 0) and (u & 0x7FFFFFFF) != 0
        if not canonical:
            repacked = int(np.asarray(pack_step_sign(jnp.float32(s2),
                                                     jnp.float32(g2))))
            assert repacked != int(np.int32(np.uint32(u))), hex(u)

        # The health scan's 'step' invariant flags EXACTLY the states whose
        # value doesn't survive their own serialization: every non-canonical
        # flip except the absorbed zero class.
        prog = make_program("2u")
        flagged = bool(np.asarray(validate_planes(
            prog, (jnp.zeros((1,), jnp.float32),
                   jnp.asarray([s2], jnp.float32),
                   jnp.asarray([g2], jnp.float32))))[0])
        assert flagged == ((not canonical) and not absorbed_zero), \
            (hex(u), s2, g2)

else:

    def test_property_tests_need_hypothesis():
        pytest.skip("hypothesis not installed — property tests not collected "
                    "(pip install -r requirements-dev.txt)")
