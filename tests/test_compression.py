"""Gradient compression: int8 + error feedback."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.parallel.compression import (
    quantize_int8, dequantize_int8, ef_init, compress_grads,
    decompress_grads, wire_bytes)


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3.0, (256, 128)), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert q.dtype == jnp.int8
    assert float(jnp.max(err)) <= float(s) * 0.5 + 1e-6  # half-ulp symmetric


def test_error_feedback_telescopes():
    """Sum of (compressed + EF) over steps converges to the true sum: the
    EF residual never grows (it's re-quantized each step)."""
    rng = np.random.default_rng(1)
    grads = {"w": jnp.zeros((64, 64))}
    ef = ef_init(grads)
    true_sum = np.zeros((64, 64), np.float32)
    sent_sum = np.zeros((64, 64), np.float32)
    for t in range(50):
        g = {"w": jnp.asarray(rng.normal(0, 1.0, (64, 64)), jnp.float32)}
        true_sum += np.asarray(g["w"])
        q, s, ef = compress_grads(g, ef)
        sent = decompress_grads(q, s)
        sent_sum += np.asarray(sent["w"])
    # residual bounded by one quantization step, NOT accumulating over t
    resid = np.abs(true_sum - sent_sum)
    assert resid.max() < 0.2, resid.max()


def test_wire_bytes_4x_reduction():
    grads = {"a": jnp.zeros((1024, 1024)), "b": jnp.zeros((512,))}
    full = wire_bytes(grads, compressed=False)
    comp = wire_bytes(grads, compressed=True)
    assert comp < full / 3.9


def test_sgd_with_compression_matches_uncompressed():
    """Toy quadratic: EF-int8 SGD converges to the same optimum."""
    rng = np.random.default_rng(2)
    target = jnp.asarray(rng.normal(0, 1, (32,)), jnp.float32)

    def run(compressed):
        w = jnp.zeros((32,))
        ef = {"w": jnp.zeros((32,))}
        for t in range(300):
            g = {"w": 2 * (w - target)}
            if compressed:
                q, s, ef = compress_grads(g, ef)
                g = decompress_grads(q, s)
            w = w - 0.05 * g["w"]
        return w

    w_full = run(False)
    w_comp = run(True)
    np.testing.assert_allclose(np.asarray(w_comp), np.asarray(target),
                               atol=0.05)
    np.testing.assert_allclose(np.asarray(w_comp), np.asarray(w_full),
                               atol=0.05)
