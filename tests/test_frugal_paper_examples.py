"""Worked examples from the paper, bit-exact (Figures 1, 2, 3)."""
import numpy as np
import jax.numpy as jnp

from repro.core.reference import frugal1u_median_scalar, frugal1u_scalar
from repro.core import frugal1u_init, frugal1u_process


def _alg1_trace(stream):
    """Algorithm 1 trace via the scalar reference."""
    trace, m = [], 0.0
    for s in stream:
        m = frugal1u_median_scalar([s], m)
        trace.append(m)
    return trace


def test_figure1_median_example():
    # Paper Fig. 1: stream 4 2 1 5 3 2 5 4 -> estimates 1 2 1 2 3 2 3 4
    stream = [4, 2, 1, 5, 3, 2, 5, 4]
    assert _alg1_trace(stream) == [1, 2, 1, 2, 3, 2, 3, 4]


def test_figure2_gapped_domain_example():
    # Paper Fig. 2: stream 1 10 10 1 10 1 10 1 -> estimates 1 2 3 2 3 2 3 2
    stream = [1, 10, 10, 1, 10, 1, 10, 1]
    assert _alg1_trace(stream) == [1, 2, 3, 2, 3, 2, 3, 2]


def test_figure3_adversarial_ascending():
    # Paper Fig. 3 / Example 4.1: ascending stream chases every item.
    stream = list(range(1, 9))
    assert _alg1_trace(stream) == [1, 2, 3, 4, 5, 6, 7, 8]


def test_alg2_reduces_to_alg1_when_updates_always_fire():
    # Algorithm 2 with q=1/2 and rand always > 1/2 is Algorithm 1 exactly.
    stream = [4, 2, 1, 5, 3, 2, 5, 4]
    rands = [0.9] * len(stream)
    trace = []
    frugal1u_scalar(stream, rands, quantile=0.5, m=0.0, trace=trace)
    assert trace == [1, 2, 1, 2, 3, 2, 3, 4]


def test_vectorized_matches_figure1():
    # JAX path: the Fig. 1 stream replicated over 4 groups.
    stream = jnp.array([4, 2, 1, 5, 3, 2, 5, 4], dtype=jnp.float32)
    G = 4
    items = jnp.tile(stream[:, None], (1, G))
    rand = jnp.full_like(items, 0.9)
    st = frugal1u_init(G)
    st, trace = frugal1u_process(st, items, rand=rand, return_trace=True)
    np.testing.assert_array_equal(np.asarray(st.m), np.full(G, 4.0))
    np.testing.assert_array_equal(
        np.asarray(trace)[:, 0], np.array([1, 2, 1, 2, 3, 2, 3, 4], dtype=np.float32)
    )


def test_rank_quantile_semantics_out_of_domain_ok():
    # Fig. 2 point: estimates 2/3 are not in the {1, 10} domain but are
    # rank-correct. relative mass error of 3 for a {1,10} bernoulli stream:
    from repro.core.reference import relative_mass_error

    stream = sorted([1, 10, 10, 1, 10, 1, 10, 1])
    err = relative_mass_error(3.0, stream, 0.5)
    assert abs(err) <= 0.25  # within a half item of the median rank
