"""Chaos suite: deterministic fault injection across the resilience layer.

Four guarantees under test (DESIGN.md §12):

  * kill-anywhere ingest  — a stream that dies at ANY chunk boundary
    surfaces as a resumable StreamInterrupted; re-feeding the same stream
    with skip_items=err.items_applied ends bit-identical to the
    uninterrupted run, for every registered lane program × every backend;
  * checksummed restore   — a committed checkpoint whose bytes rot after
    commit (truncated / garbled / silently-rewritten shard) is quarantined
    at restore and the scan falls back to the newest step that verifies;
  * torn-write exclusion  — a kill at any checkpoint-protocol phase never
    exposes a torn step as committed, and the save is re-runnable;
  * self-healing lanes    — an in-memory bit flip is caught by the
    program's declared invariants, and a quarantined lane's future is
    bit-exact with a lane freshly created at the same cursor position.

The kill matrix sweeps CHAOS_SEEDS (comma-separated env, default "0") —
CI's chaos job runs three seeds so the kill point moves across runs while
every individual run stays deterministic.
"""
import dataclasses
import os
import zlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.api import FleetSpec, QuantileFleet, StreamCursor, TopologySpec
from repro.data.pipeline import DataConfig, RetryPolicy, SyntheticCorpus, \
    with_retry
from repro.resilience import (CheckpointKilled, Fault, FaultPlan,
                              LaneCorruptionError, StreamInterrupted, chaos)
from repro.serve.slo import SLOFleet
from repro.train import checkpoint as ckpt

SEEDS = tuple(int(s) for s in os.environ.get("CHAOS_SEEDS", "0").split(","))

G, T, CHUNK = 4, 200, 32
N_CHUNKS = -(-T // CHUNK)
# "sharded"/"mesh2d" are placement legs spelled via TopologySpec: the 1-D
# lane mesh and the 2-D (data × lane) mesh. Crash consistency must hold
# under every placement — a 2-D interrupt still lands on a chunk boundary
# and each chunk belongs wholly to one replica.
BACKENDS = ("jnp", "fused", "sharded", "mesh2d")


def _data(seed=4):
    rng = np.random.default_rng(seed)
    return rng.normal(5.0, 2.0, size=(T, G)).astype(np.float32)


def _blocks(data):
    # Ragged on purpose: interrupts must land on RE-CHUNKED boundaries,
    # not on source-block boundaries.
    return [data[0:37], data[37:81], data[81:]]


def _spec(program, backend, **kw):
    topo = None
    if backend in ("sharded", "mesh2d"):
        topo = TopologySpec(data=2 if backend == "mesh2d" else 1,
                            lanes=min(2, len(jax.devices())))
        backend = "fused"
    return FleetSpec(num_groups=G, quantiles=(0.5, 0.9), backend=backend,
                     chunk_t=CHUNK, topology=topo, program=program, **kw)


def _assert_fleet_equal(a: QuantileFleet, b: QuantileFleet, what=""):
    assert np.array_equal(a.estimate(), b.estimate()), what
    for f, pa, pb in zip(a.spec.program.layout.plane_fields,
                         a._lane_sketch().planes(),
                         b._lane_sketch().planes()):
        assert np.array_equal(np.asarray(pa), np.asarray(pb)), (what, f)
    assert int(a.cursor.t_offset) == int(b.cursor.t_offset), what
    assert int(a.cursor.seed) == int(b.cursor.seed), what


# --------------------------------------------------------------- kill matrix
@pytest.mark.parametrize("chaos_seed", SEEDS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_kill_anywhere_resume_bit_exact(lane_program, backend, chaos_seed):
    """Kill ingest at a seeded chunk boundary; resume must be bit-exact."""
    # Spread kill points across the (program, seed) grid deterministically.
    plan_seed = chaos_seed * 1009 + \
        zlib.crc32(lane_program.family.encode()) % 997
    plan = FaultPlan.seeded_kill(plan_seed, N_CHUNKS)
    kill_after = plan.faults[0].at

    data = _data()
    spec = _spec(lane_program, backend)
    ref = QuantileFleet.create(spec, seed=3).ingest_stream(
        iter(_blocks(data)), chunk_t=CHUNK)

    fleet = QuantileFleet.create(spec, seed=3)
    with chaos.armed(plan):
        with pytest.raises(StreamInterrupted) as ei:
            fleet.ingest_stream(iter(_blocks(data)), chunk_t=CHUNK)
    err = ei.value
    assert err.items_applied == min(kill_after * CHUNK, T)
    assert err.fleet is not None
    assert int(err.fleet.cursor.t_offset) == err.items_applied

    resumed = err.fleet.ingest_stream(iter(_blocks(data)), chunk_t=CHUNK,
                                      skip_items=err.items_applied)
    _assert_fleet_equal(ref, resumed,
                        (lane_program.family, backend, kill_after))


def test_source_exception_discards_staged_partial():
    """A source dying mid-block commits only FULL chunks: the 8 staged rows
    beyond the first chunk_t boundary are discarded, not half-applied."""
    data = _data()
    spec = _spec("2u", "fused")

    def dying():
        yield data[:40]                  # 32 applied + 8 staged
        raise OSError("socket reset")

    fleet = QuantileFleet.create(spec, seed=3)
    with pytest.raises(StreamInterrupted) as ei:
        fleet.ingest_stream(dying(), chunk_t=CHUNK)
    err = ei.value
    assert err.items_applied == CHUNK

    ref = QuantileFleet.create(spec, seed=3).ingest_stream(
        iter(_blocks(data)), chunk_t=CHUNK)
    resumed = err.fleet.ingest_stream(iter(_blocks(data)), chunk_t=CHUNK,
                                      skip_items=err.items_applied)
    _assert_fleet_equal(ref, resumed)


def test_malformed_chunks_still_raise_value_error():
    """Shape errors are caller bugs, not transient faults — they must stay
    plain ValueError, never a resumable StreamInterrupted."""
    fleet = QuantileFleet.create(_spec("2u", "fused"), seed=0)
    with pytest.raises(ValueError):
        fleet.ingest_stream([np.zeros((5, 3), np.float32)])


def test_skip_items_validation():
    fleet = QuantileFleet.create(_spec("2u", "fused"), seed=0)
    with pytest.raises(ValueError):
        fleet.ingest_stream([_data()], skip_items=-1)


def test_seeded_kill_plans_are_deterministic():
    a, b = FaultPlan.seeded_kill(7, 10), FaultPlan.seeded_kill(7, 10)
    assert a.faults == b.faults
    assert 1 <= a.faults[0].at <= 10


# ---------------------------------------------------------- self-healing lanes
def _with_planes(fleet: QuantileFleet, planes) -> QuantileFleet:
    sk = fleet._lane_sketch()
    return dataclasses.replace(
        fleet, state=sk.with_planes(tuple(jnp.asarray(p) for p in planes)))


def _corrupted(fleet: QuantileFleet, plane: int, lane: int,
               value: float) -> QuantileFleet:
    planes = [np.asarray(p).copy() for p in fleet._lane_sketch().planes()]
    planes[plane][lane] = value
    return _with_planes(fleet, planes)


@pytest.mark.parametrize("backend", ("jnp", "fused"))
def test_bitflip_quarantine_heal_bit_exact(backend):
    """An injected in-memory bit flip is detected by the program's declared
    invariants; quarantine re-initializes the lane in place, and its future
    is bit-exact with a lane CREATED at the current cursor (counter-hashed
    uniforms have no history)."""
    data = _data()
    spec = _spec("2u", backend, health="quarantine")
    t1 = 96                                    # 3 whole chunks
    # sign plane (index 2), lane 3, bit 22: ±1.0 -> ±1.5, out of domain.
    # The flip lands in the LAST chunk window before the health scan —
    # earlier flips can be legitimately overwritten by later ticks (the
    # rule rewrites sign in-domain), which is absorption, not detection.
    plan = FaultPlan(faults=[Fault(kind="flip", at=70, plane=2, lane=3,
                                   bit=22)])

    fleet = QuantileFleet.create(spec, seed=3)
    with chaos.armed(plan):
        fleet = fleet.ingest_stream([data[:t1]], chunk_t=CHUNK)
    assert plan.fired() == 1
    rep = fleet.health()
    assert not rep.healthy and rep.lane_ids == (3,)

    fleet, rep = fleet.check_health()
    assert rep.quarantined == 1
    assert fleet.health().healthy
    fleet = fleet.ingest_stream([data[t1:]], chunk_t=CHUNK)

    # Lane 3 == the same lane of a fleet whose lanes STARTED at tick t1.
    fresh = QuantileFleet.create(
        spec, seed=3, cursor=StreamCursor.create(seed=3, t_offset=t1))
    fresh = fresh.ingest_stream([data[t1:]], chunk_t=CHUNK)
    for pa, pb in zip(fleet._lane_sketch().planes(),
                      fresh._lane_sketch().planes()):
        assert np.asarray(pa)[3] == np.asarray(pb)[3]

    # Every OTHER lane is untouched: bit-exact with the uninterrupted run.
    ref = QuantileFleet.create(spec, seed=3).ingest_stream([data],
                                                           chunk_t=CHUNK)
    keep = np.ones((spec.num_lanes,), bool)
    keep[3] = False
    for pa, pb in zip(fleet._lane_sketch().planes(),
                      ref._lane_sketch().planes()):
        assert np.array_equal(np.asarray(pa)[keep], np.asarray(pb)[keep])


def test_health_policy_raise():
    fleet = QuantileFleet.create(
        FleetSpec(num_groups=G, backend="jnp", health="raise"),
        seed=0).ingest(_data())
    bad = _corrupted(fleet, plane=2, lane=1, value=-1.5)
    with pytest.raises(LaneCorruptionError, match="1/4 lanes"):
        bad.check_health()
    # scan-only health() never raises
    assert bad.health().corrupt_lanes == 1


def test_health_policy_ignore_reports_without_mutating():
    fleet = QuantileFleet.create(
        FleetSpec(num_groups=G, backend="jnp", health="ignore"),
        seed=0).ingest(_data())
    bad = _corrupted(fleet, plane=0, lane=2, value=np.nan)
    out, rep = bad.check_health()
    assert out is bad
    assert rep.corrupt_lanes == 1 and rep.quarantined == 0


def test_healthy_fleet_check_is_identity():
    fleet = QuantileFleet.create(
        FleetSpec(num_groups=G, backend="jnp", health="quarantine"),
        seed=0).ingest(_data())
    out, rep = fleet.check_health()
    assert out is fleet and rep.healthy and rep.quarantined == 0


def test_step_plane_roundtrip_invariant_catches_unpackable_state():
    """A step value the packed (step, sign) word cannot represent — e.g. a
    huge out-of-range float planted by corruption — flags even though it is
    finite (the 'step' domain round-trips through core.packing)."""
    fleet = QuantileFleet.create(
        FleetSpec(num_groups=G, backend="jnp", health="ignore"),
        seed=0).ingest(_data())
    bad = _corrupted(fleet, plane=1, lane=0, value=1e38)  # > 2^32 clip range
    assert bad.health().lane_ids == (0,)


def test_fleet_spec_rejects_unknown_health_policy():
    with pytest.raises(ValueError, match="health"):
        FleetSpec(num_groups=4, health="retry-forever")


def test_slo_fleet_quarantine_accumulates():
    fl = SLOFleet(seed=1, capacity=4)
    for i in range(40):
        fl.observe("api", "ttft_q99_ms", 100.0 + i)
        fl.observe("api", "tok_q50_ms", 10.0 + 0.1 * i)
    fl.flush()
    assert fl.check_health().healthy and fl.quarantined_total == 0

    sk = fl._fleet._lane_sketch()
    planes = [np.asarray(p).copy() for p in sk.planes()]
    planes[2][0] = 5.0                       # sign plane garbage, lane 0
    fl._fleet = dataclasses.replace(
        fl._fleet, state=sk.with_planes(tuple(jnp.asarray(p)
                                              for p in planes)))
    rep = fl.check_health()
    assert rep.quarantined == 1 and fl.quarantined_total == 1
    assert fl.last_health is rep
    assert fl.check_health().healthy


# ------------------------------------------------------- checkpoint integrity
def _two_step_dir(tmp_path, spec, data):
    d = str(tmp_path)
    f1 = QuantileFleet.create(spec, seed=1).ingest(data)
    f1.checkpoint(d, step=1)
    f2 = f1.ingest(data)
    f2.checkpoint(d, step=2)
    return d, f1, f2


@pytest.mark.parametrize("mode", ("truncate", "garble", "rewrite"))
def test_corrupt_newest_step_falls_back_and_quarantines(tmp_path, mode):
    """Post-commit rot on the newest step: restore verifies, quarantines it
    (marker dropped, dir renamed *.corrupt) and falls back to step 1 —
    'rewrite' leaves a perfectly valid npz container, so only the format-4
    manifest CRC32 can catch it."""
    data = _data()
    spec = FleetSpec(num_groups=G, backend="fused")
    d, f1, f2 = _two_step_dir(tmp_path, spec, data)

    chaos.corrupt_leaf_bytes(os.path.join(d, "step_00000002"), mode)
    restored = QuantileFleet.restore(d, spec)
    _assert_fleet_equal(restored, f1, mode)
    assert ckpt.committed_steps(d) == [1]
    assert os.path.isdir(os.path.join(d, "step_00000002.corrupt"))

    # Re-ingesting from the fallback reproduces step 2 bit-exactly.
    _assert_fleet_equal(restored.ingest(data), f2, mode)


def test_pinned_corrupt_step_raises_and_quarantines(tmp_path):
    """With step= pinned there is no silent substitution: the corruption
    error propagates (named 'corrupt or truncated') and the step is still
    quarantined."""
    data = _data()
    spec = FleetSpec(num_groups=G, backend="fused")
    d, f1, _ = _two_step_dir(tmp_path, spec, data)
    chaos.corrupt_leaf_bytes(os.path.join(d, "step_00000002"), "rewrite")
    with pytest.raises(ckpt.CheckpointCorruptError,
                       match="corrupt or truncated"):
        QuantileFleet.restore(d, spec, step=2)
    assert ckpt.committed_steps(d) == [1]
    assert os.path.isdir(os.path.join(d, "step_00000002.corrupt"))


def test_every_step_corrupt_raises_named_error(tmp_path):
    data = _data()
    spec = FleetSpec(num_groups=G, backend="fused")
    d, _, _ = _two_step_dir(tmp_path, spec, data)
    chaos.corrupt_leaf_bytes(os.path.join(d, "step_00000001"), "garble")
    chaos.corrupt_leaf_bytes(os.path.join(d, "step_00000002"), "truncate")
    with pytest.raises(ckpt.CheckpointCorruptError, match="verifies"):
        QuantileFleet.restore(d, spec)
    assert ckpt.committed_steps(d) == []


@pytest.mark.parametrize("backend", ("fused", "mesh2d"))
def test_dropped_shard_read_skips_to_older_step(tmp_path, backend):
    """A shard read failing with ENOENT (GC race / transient FS) is a SKIP,
    not corruption: restore falls back without quarantining — the step's
    bytes may be fine next scan. Same contract under the 2-D placement
    (checkpoints store merged canonical lanes, so the drop/fallback path is
    placement-independent — pinned here anyway)."""
    data = _data()
    spec = _spec("2u", backend)
    d, f1, _ = _two_step_dir(tmp_path, spec, data)
    with chaos.armed(FaultPlan(faults=[Fault(kind="drop_shard")])):
        restored = QuantileFleet.restore(d, spec)
    _assert_fleet_equal(restored, f1)
    assert ckpt.committed_steps(d) == [1, 2]   # nothing quarantined


@pytest.mark.parametrize("phase", ("after_leaves", "before_marker"))
def test_checkpoint_kill_never_exposes_torn_step(tmp_path, phase):
    """Kill the writer between ANY two protocol phases: the step must not
    be visible as committed, older steps must restore, and re-running the
    save must succeed."""
    data = _data()
    spec = FleetSpec(num_groups=G, backend="fused")
    d = str(tmp_path)
    f1 = QuantileFleet.create(spec, seed=1).ingest(data)
    f1.checkpoint(d, step=1)
    f2 = f1.ingest(data)
    with chaos.armed(FaultPlan(faults=[Fault(kind="ckpt_kill",
                                             phase=phase)])):
        with pytest.raises(CheckpointKilled):
            f2.checkpoint(d, step=2)
    assert ckpt.committed_steps(d) == [1]
    _assert_fleet_equal(QuantileFleet.restore(d, spec), f1, phase)

    f2.checkpoint(d, step=2)                   # crash recovery: re-save
    assert ckpt.committed_steps(d) == [1, 2]
    _assert_fleet_equal(QuantileFleet.restore(d, spec), f2, phase)


def test_format3_unchecksummed_save_still_restores(tmp_path):
    import json
    data = _data()
    spec = FleetSpec(num_groups=G, backend="fused")
    d = str(tmp_path)
    f1 = QuantileFleet.create(spec, seed=1).ingest(data)
    ckpt.save_checkpoint(d, 1, f1.checkpoint_state(), checksum=False)
    with open(os.path.join(d, "step_00000001", "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == 3 and "crc32" not in manifest
    restored = QuantileFleet.restore(d, spec)
    _assert_fleet_equal(restored, f1)


# ----------------------------------------------------------- pipeline retries
def test_pipeline_retry_backoff_then_bit_identical_batch():
    sleeps = []
    corpus = SyntheticCorpus(
        DataConfig(), retry=RetryPolicy(max_retries=3, backoff_s=0.01,
                                        backoff_factor=2.0, deadline_s=60.0),
        _sleep=sleeps.append)
    ref = SyntheticCorpus(DataConfig()).batch(5)
    plan = FaultPlan(faults=[Fault(kind="stream", at=1, scope="pipeline"),
                             Fault(kind="stream", at=2, scope="pipeline")])
    with chaos.armed(plan):
        batch = corpus.batch(5)
    assert sleeps == [0.01, 0.02]
    # the retried draw keys on (seed, host, step): bit-identical
    assert np.array_equal(batch["tokens"], ref["tokens"])
    assert np.array_equal(batch["targets"], ref["targets"])


def test_pipeline_retry_exhaustion_reraises():
    sleeps = []
    corpus = SyntheticCorpus(
        DataConfig(), retry=RetryPolicy(max_retries=2, backoff_s=0.01),
        _sleep=sleeps.append)
    plan = FaultPlan(faults=[Fault(kind="stream", at=i, scope="pipeline")
                             for i in range(1, 6)])
    with chaos.armed(plan):
        with pytest.raises(chaos.StreamFault):
            corpus.batch(0)
    assert len(sleeps) == 2                    # 3 attempts, 2 backoffs


def test_retry_deadline_cuts_backoff_short():
    clock = [0.0]

    def fn():
        chaos.count_event("pipeline")
        return "ok"

    plan = FaultPlan(faults=[Fault(kind="stream", at=i, scope="pipeline")
                             for i in range(1, 10)])
    with chaos.armed(plan):
        with pytest.raises(chaos.StreamFault):
            with_retry(fn, RetryPolicy(max_retries=8, backoff_s=1.0,
                                       backoff_factor=2.0, deadline_s=3.0),
                       sleep=lambda s: clock.__setitem__(0, clock[0] + s),
                       clock=lambda: clock[0])
    assert clock[0] == 3.0                     # slept 1 + 2, then gave up


def test_no_retry_policy_means_no_retry():
    corpus = SyntheticCorpus(DataConfig())    # retry=None
    plan = FaultPlan(faults=[Fault(kind="stream", at=1, scope="pipeline")])
    with chaos.armed(plan):
        with pytest.raises(chaos.StreamFault):
            corpus.batch(0)


# ----------------------------------------------------------------- harness
def test_hooks_are_noops_when_disarmed():
    assert chaos.active() is None
    chaos.count_event("ingest")                # no raise
    chaos.on_checkpoint_phase("after_leaves")
    chaos.on_restore_shard("/nonexistent")
    sk = QuantileFleet.create(_spec("2u", "jnp"), seed=0)._lane_sketch()
    assert chaos.corrupt_sketch(sk, 0, 100) is sk


def test_armed_restores_previous_plan():
    outer, inner = FaultPlan(), FaultPlan()
    with chaos.armed(outer):
        with chaos.armed(inner):
            assert chaos.active() is inner
        assert chaos.active() is outer
    assert chaos.active() is None
